"""RLVR trainer (§5.2): GRPO with PPO-clip vs GRPO with VACO filtering.

Protocol (Noukhovitch et al., 2025 / paper App. C.2): each phase freezes
the policy as β, generates N minibatches of grouped completions, labels
them with the binary verifier, then takes N updates — minibatch k is
consumed with forward lag k.  Table 2 hyper-parameters are defaults
(clip 0.2/0.272 DAPO-style; TV threshold δ=0.05; 1 PPO epoch).

The trainer runs on the unified async runtime: the serve side
(``ForwardLagGenerator.generate_minibatch``) produces into a
staleness-tagged :class:`TrajectoryQueue` under a lag regime —
``forward_n`` reproduces the paper's phase-locked schedule (bit-for-bit
vs. the legacy in-process loop at fixed seed), ``threaded`` runs a real
producer thread against the consuming learner.  Every update publishes a
new version to the :class:`PolicyStore`; item staleness is read off the
queue tags instead of being scripted.

Because no pretrained base model is downloadable offline, the runner
first *creates* a base model with a supervised warm-start on synthetic
chain traces (repro.data.mathgen), then runs RL exactly as the paper does
on Qwen2.5-0.5B-base.  The advantage realignment ratio is 1 (fresh data
each phase — no backward lag), matching App. C.2.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.losses import GRPOConfig, group_advantages, grpo_token_loss
from repro.core.tv_filter import tv_estimate
from repro.data.mathgen import MathTaskDataset
from repro.metrics.runtime_metrics import collect_runtime_stats
from repro.models.registry import ModelBundle
from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import NULL_TRACER, Tracer
from repro.checkpoint import (
    latest_checkpoint,
    load_checkpoint,
    save_checkpoint,
)
from repro.optim import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
)
from repro.resilience import (
    BackoffPolicy,
    FaultInjector,
    NULL_INJECTOR,
    tree_all_finite,
)
from repro.rollout.async_engine import ForwardLagGenerator, RLVRMinibatch
from repro.rollout.sampler import score_tokens
from repro.runtime import (
    PolicyStore,
    TrajectoryQueue,
    make_controller,
    make_regime,
    parse_controller_spec,
    spec_from_legacy,
)
from repro.runtime.serve_producer import ServeRolloutProducer


@dataclass(frozen=True)
class RLVRHyperparams:
    algorithm: str = "grpo"       # grpo (ppo-clip) | grpo_vaco
    clip_low: float = 0.2
    clip_high: float = 0.272      # DAPO clip-higher
    delta: float = 0.05           # VACO TV threshold (Table 2)
    entropy_coef: float = 0.0
    lr: float = 1e-4              # paper: 1e-6 on a 0.5B; scaled for ~1M
    weight_decay: float = 0.0
    max_grad_norm: float = 1.0
    n_minibatches: int = 4        # N — the forward-lag knob
    prompts_per_minibatch: int = 16   # paper: 32
    completions_per_prompt: int = 4   # paper: 8
    max_new_tokens: int = 8
    temperature: float = 1.0
    warmup_steps: int = 300       # supervised base-model creation
    warmup_lr: float = 3e-3
    warmup_batch: int = 64
    # --- runtime ---
    runtime: str = "forward_n"    # forward_n | threaded
    store_capacity: int = 4       # policy snapshot ring size
    queue_maxsize: int = 4        # producer backpressure (threaded)
    # Lag controller: a "name:key=val,..." spec (see
    # runtime.controllers).  None falls back to the legacy admission
    # triple below via the deprecation shim.
    controller: Optional[str] = None
    # --- legacy admission triple (deprecated; use `controller`) ---
    admission: str = "pass_through"  # pass_through|max_lag|tv_gate
    #                                 # |tv_gate_tokenwise
    max_lag: int = 8
    admission_mode: str = "drop"  # tv_gate*: drop|downweight
    get_timeout: float = 300.0    # learner wait per item (threaded)
    max_refills: int = 50         # phase-locked starvation bound
    # --- producer ---
    producer: str = "legacy"      # legacy (ForwardLagGenerator) | serve
    # serve producer: force generation from the learner's k-back
    # snapshot (None = track the freshest swapped-in weights).
    forced_lag: Optional[int] = None
    engine_num_blocks: int = 64   # serve producer: paged-pool size
    engine_block_size: int = 8
    engine_max_batch: int = 8
    engine_swap_interval: int = 1
    engine_prefix_cache: bool = False
    engine_speculate_k: int = 0
    # --- resilience (see repro.resilience) ---
    # Fault plan spec: ";"-joined "kind:key=val,..." chunks, e.g.
    # "producer_crash:at_step=40;nan_publish:at_publish=7".  Empty =
    # no injection (NULL_INJECTOR, zero overhead on every hook).
    fault_plan: str = ""
    fault_seed: int = 0
    # Watchdog: >0 supervises threaded producers with bounded-retry
    # restarts under seeded exponential backoff; 0 = crash-fast (the
    # pre-supervision behavior, and what phase-locked runs use).
    watchdog_restarts: int = 0
    watchdog_backoff_ms: float = 50.0
    # Serve producer: per-request wall-clock budget; timed-out requests
    # retire with finish_reason="timeout" and release their pages.
    request_deadline_s: Optional[float] = None
    # Quarantine non-finite publishes and skip+restore non-finite
    # learner steps (restores the last finite state, or the newest
    # checkpoint under `guard_checkpoint_dir` when set).
    finiteness_guard: bool = True
    guard_checkpoint_dir: Optional[str] = None


class RLVRTrainState(NamedTuple):
    params: Any
    opt_state: AdamWState
    updates: jax.Array


def make_update_step(bundle: ModelBundle, hp: RLVRHyperparams,
                     prompt_len: int):
    grpo_cfg = GRPOConfig(
        clip_low=hp.clip_low, clip_high=hp.clip_high,
        use_vaco=(hp.algorithm == "grpo_vaco"), delta=hp.delta,
        entropy_coef=hp.entropy_coef,
    )
    opt_cfg = AdamWConfig(lr=hp.lr, weight_decay=hp.weight_decay, eps=1e-8)

    def loss_fn(params, tokens, log_beta, mask, advantages):
        log_pi, entropy, _ = score_tokens(
            bundle, params, tokens, prompt_len)
        loss, aux = grpo_token_loss(
            log_pi=log_pi, log_beta=log_beta, advantages=advantages,
            token_mask=mask, cfg=grpo_cfg,
        )
        aux["token_entropy"] = jnp.sum(entropy * mask) / jnp.maximum(
            jnp.sum(mask), 1.0)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    @jax.jit
    def update(state: RLVRTrainState, tokens, log_beta, mask, advantages):
        (loss, aux), grads = grad_fn(
            state.params, tokens, log_beta, mask, advantages)
        grads, gnorm = clip_by_global_norm(grads, hp.max_grad_norm)
        params, opt_state = adamw_update(
            grads, state.opt_state, state.params, opt_cfg)
        aux = dict(aux, loss=loss, grad_norm=gnorm)
        return RLVRTrainState(params, opt_state, state.updates + 1), aux

    return update


def make_split_update_step(bundle: ModelBundle, hp: RLVRHyperparams,
                           prompt_len: int):
    """The fused update split at the gradient boundary, for controllers
    with ``needs_gradients`` (GAC): ``grad_step`` returns the raw
    gradients so the controller can inspect/rescale them on the host,
    ``apply_step`` then clips and applies.  Same math as
    :func:`make_update_step`, two dispatches instead of one."""
    grpo_cfg = GRPOConfig(
        clip_low=hp.clip_low, clip_high=hp.clip_high,
        use_vaco=(hp.algorithm == "grpo_vaco"), delta=hp.delta,
        entropy_coef=hp.entropy_coef,
    )
    opt_cfg = AdamWConfig(lr=hp.lr, weight_decay=hp.weight_decay, eps=1e-8)

    def loss_fn(params, tokens, log_beta, mask, advantages):
        log_pi, entropy, _ = score_tokens(
            bundle, params, tokens, prompt_len)
        loss, aux = grpo_token_loss(
            log_pi=log_pi, log_beta=log_beta, advantages=advantages,
            token_mask=mask, cfg=grpo_cfg,
        )
        aux["token_entropy"] = jnp.sum(entropy * mask) / jnp.maximum(
            jnp.sum(mask), 1.0)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    @jax.jit
    def grad_step(state: RLVRTrainState, tokens, log_beta, mask,
                  advantages):
        (loss, aux), grads = grad_fn(
            state.params, tokens, log_beta, mask, advantages)
        return grads, dict(aux, loss=loss)

    @jax.jit
    def apply_step(state: RLVRTrainState, grads):
        grads, gnorm = clip_by_global_norm(grads, hp.max_grad_norm)
        params, opt_state = adamw_update(
            grads, state.opt_state, state.params, opt_cfg)
        return RLVRTrainState(params, opt_state, state.updates + 1), gnorm

    return grad_step, apply_step


def make_warmup_step(bundle: ModelBundle, hp: RLVRHyperparams):
    """Supervised next-token warm-start (creates the 'base model')."""
    opt_cfg = AdamWConfig(lr=hp.warmup_lr, eps=1e-8)

    def loss_fn(params, tokens, mask):
        out = bundle.forward(params, tokens)
        logits = out.logits[:, :-1]
        targets = tokens[:, 1:]
        mask = mask[:, 1:]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    grad_fn = jax.value_and_grad(loss_fn)

    @jax.jit
    def step(state: RLVRTrainState, tokens, mask):
        loss, grads = grad_fn(state.params, tokens, mask)
        grads, _ = clip_by_global_norm(grads, hp.max_grad_norm)
        params, opt_state = adamw_update(
            grads, state.opt_state, state.params, opt_cfg)
        return RLVRTrainState(params, opt_state, state.updates), loss

    return step


@dataclass
class RLVRPhaseLog:
    mean_reward: float
    tv: float
    frac_filtered: float      # VACO filter rate / PPO clip rate
    filter_active: float
    staleness: int            # queue-observed lag at consume time
    weight: float = 1.0       # admission downweight (1.0 = full)


@dataclass
class RLVRResult:
    eval_accuracy: List[float]
    phase_logs: List[RLVRPhaseLog]
    runtime_stats: Dict[str, Any] = field(default_factory=dict)


class RLVRTrainer:
    """Drives warmup + the queue-fed forward-lag RL loop."""

    def __init__(
        self,
        bundle: ModelBundle,
        dataset: MathTaskDataset,
        hp: RLVRHyperparams,
        seed: int = 0,
        tracer: Optional[Tracer] = None,
        metrics: Optional[MetricsRegistry] = None,
    ) -> None:
        self.bundle = bundle
        self.dataset = dataset
        self.hp = hp
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._h_step = self.metrics.histogram("train_step_s")
        self.metrics.register_producer(
            "train", lambda: collect_runtime_stats(self.store, self.queue))
        key = jax.random.PRNGKey(seed)
        params = bundle.init(key)
        self.state = RLVRTrainState(
            params=params, opt_state=adamw_init(params),
            updates=jnp.zeros((), jnp.int32),
        )
        self.generator = ForwardLagGenerator(
            bundle, dataset,
            n_minibatches=hp.n_minibatches,
            prompts_per_minibatch=hp.prompts_per_minibatch,
            completions_per_prompt=hp.completions_per_prompt,
            max_new_tokens=hp.max_new_tokens,
            temperature=hp.temperature,
            seed=seed + 1,
            version_fn=lambda: self.store.version,
        )
        self._update = make_update_step(bundle, hp, dataset.prompt_len)
        self._warmup = make_warmup_step(bundle, hp)

        # --- runtime assembly ------------------------------------------------
        # Resilience: one shared injector threads through every fault
        # site (producer, publish, queue, engine, learner); the
        # supervisor policy restarts crashed producer threads with
        # seeded backoff.  Both are inert by default.
        self.injector = (
            FaultInjector(hp.fault_plan, seed=hp.fault_seed,
                          registry=self.metrics, tracer=self.tracer)
            if hp.fault_plan else NULL_INJECTOR)
        self.supervisor = (
            BackoffPolicy(base_ms=hp.watchdog_backoff_ms,
                          max_restarts=hp.watchdog_restarts, seed=seed)
            if hp.watchdog_restarts > 0 else None)
        self._last_good: Optional[RLVRTrainState] = None
        self._learner_steps = 0
        self.nonfinite_skipped = 0
        self.store = PolicyStore(params, capacity=hp.store_capacity,
                                 tracer=self.tracer,
                                 injector=self.injector,
                                 guard_finite=hp.finiteness_guard,
                                 registry=self.metrics)
        # Controller: a spec string wins; the legacy admission triple is
        # mapped through the deprecation shim (no warning here — the
        # launcher warns on actual legacy *flag* use).
        spec = (parse_controller_spec(hp.controller) if hp.controller
                else spec_from_legacy(
                    hp.admission, max_lag=hp.max_lag, delta=hp.delta,
                    mode=hp.admission_mode))
        tv_fn = token_tv_fn = None
        if spec.name == "tv_gate":
            tv_fn = self._make_tv_fn()
        elif spec.name == "tv_gate_tokenwise":
            token_tv_fn = self._make_token_tv_fn()
        self.controller = make_controller(
            spec, tv_fn=tv_fn, token_tv_fn=token_tv_fn)
        self.controller_spec = spec
        self.queue = TrajectoryQueue(
            maxsize=hp.queue_maxsize if hp.runtime == "threaded" else 0,
            admission=self.controller,
            tracer=self.tracer,
            registry=self.metrics,
            injector=self.injector,
            fallback_max_lag=hp.max_lag,
        )
        if self.controller.needs_log_pi:
            prompt_len = dataset.prompt_len

            @jax.jit
            def _score(params, tokens):
                log_pi, _, _ = score_tokens(
                    bundle, params, tokens, prompt_len)
                return log_pi

            self._score_log_pi = _score
        if self.controller.needs_gradients:
            self._grad_step, self._apply_step = make_split_update_step(
                bundle, hp, dataset.prompt_len)
        self.engine = None
        if hp.producer == "serve":
            from repro.serve.engine import ServeEngine

            self.engine = ServeEngine(
                bundle,
                store=self.store,
                num_blocks=hp.engine_num_blocks,
                block_size=hp.engine_block_size,
                max_batch=hp.engine_max_batch,
                max_seq_len=dataset.prompt_len + hp.max_new_tokens,
                swap_interval=hp.engine_swap_interval,
                temperature=hp.temperature,
                seed=seed + 2,
                prefix_cache=hp.engine_prefix_cache,
                speculate_k=hp.engine_speculate_k,
                tracer=self.tracer,
                metrics=self.metrics,
                injector=self.injector,
                request_deadline_s=hp.request_deadline_s,
            )
            self.regime = ServeRolloutProducer(
                self.store, self.queue, self.engine, dataset,
                prompts_per_minibatch=hp.prompts_per_minibatch,
                completions_per_prompt=hp.completions_per_prompt,
                max_new_tokens=hp.max_new_tokens,
                version_offset=hp.forced_lag,
                threaded=(hp.runtime == "threaded"),
                injector=self.injector,
                supervisor=self.supervisor,
            )
        elif hp.producer == "legacy":
            self.regime = make_regime(
                hp.runtime, self.store, self.queue,
                self.generator.generate_minibatch,
                forward_n=hp.n_minibatches,
                max_items=None,
                injector=self.injector,
                supervisor=self.supervisor,
            )
        else:
            raise ValueError(
                f"unknown producer {hp.producer!r} (legacy|serve)")
        self._regime_started = False

    def _make_tv_fn(self):
        """Sequence-level TV of a generated minibatch vs the current policy."""
        bundle, prompt_len = self.bundle, self.dataset.prompt_len

        @jax.jit
        def _tv(params, tokens, log_beta, mask):
            log_pi, _, _ = score_tokens(bundle, params, tokens, prompt_len)
            return tv_estimate(log_pi - log_beta, mask)

        def tv_fn(payload: RLVRMinibatch) -> float:
            params, _ = self.store.latest()
            return float(_tv(params, payload.gen.tokens,
                             payload.gen.log_beta, payload.gen.mask))

        return tv_fn

    def _make_token_tv_fn(self):
        """Per-token TV terms + producing versions for the tokenwise gate.

        Returns ``payload -> (tv_tokens, versions)`` (both flattened over
        the minibatch's mask-valid completion tokens, row-major so a
        row's version run stays contiguous), scoring against the *latest*
        policy in the store — the Eq. 8 estimator the
        ``TokenwiseTVGate`` applies per version segment.
        """
        bundle, prompt_len = self.bundle, self.dataset.prompt_len

        @jax.jit
        def _tv_terms(params, tokens, log_beta):
            log_pi, _, _ = score_tokens(bundle, params, tokens, prompt_len)
            return 0.5 * jnp.abs(jnp.exp(log_pi - log_beta) - 1.0)

        def tv_fn(payload: RLVRMinibatch):
            params, _ = self.store.latest()
            tv = np.asarray(_tv_terms(
                params, payload.gen.tokens, payload.gen.log_beta))
            valid = np.asarray(payload.gen.mask) > 0
            versions = (payload.versions if payload.versions is not None
                        else np.zeros(tv.shape, np.int64))
            return tv[valid], np.asarray(versions)[valid]

        return tv_fn

    def warmup(self, steps: Optional[int] = None) -> float:
        steps = steps if steps is not None else self.hp.warmup_steps
        loss = float("nan")
        for _ in range(steps):
            toks, mask = self.dataset.supervised_batch(
                self.hp.warmup_batch, self.hp.max_new_tokens)
            # reset the optimizer moments only once RL starts.
            self.state, loss = self._warmup(
                self.state, jnp.asarray(toks), jnp.asarray(mask))
        # fresh optimizer state for RL.
        self.state = RLVRTrainState(
            params=self.state.params,
            opt_state=adamw_init(self.state.params),
            updates=jnp.zeros((), jnp.int32),
        )
        # the warm-started model is the RL base policy.
        self.store.publish(self.state.params, event="warmup_done")
        return float(loss)

    def close(self) -> None:
        """Stop the producer (threaded regime) and close the queue."""
        self.regime.stop()

    # -- finiteness guard ----------------------------------------------------

    def _step_finite(self, aux: Dict[str, Any]) -> bool:
        """True when this step's loss and the post-update params are
        all finite (aux values are already on host)."""
        loss = aux.get("loss")
        if loss is not None and not np.all(np.isfinite(np.asarray(loss))):
            return False
        return tree_all_finite(self.state.params)

    def _restore_last_good(self) -> str:
        """Roll the train state back to the newest known-finite point;
        returns where it came from ("checkpoint" | "memory" | "none")."""
        hp = self.hp
        if hp.guard_checkpoint_dir:
            path = latest_checkpoint(hp.guard_checkpoint_dir)
            if path is not None:
                like = {"params": self.state.params,
                        "opt_state": self.state.opt_state}
                tree, _, _ = load_checkpoint(path, like)
                self.state = RLVRTrainState(
                    params=tree["params"],
                    opt_state=tree["opt_state"],
                    updates=self.state.updates)
                return "checkpoint"
        if self._last_good is not None:
            self.state = self._last_good
            return "memory"
        return "none"

    def _skip_nonfinite(self, item: Any) -> None:
        self.nonfinite_skipped += 1
        restored = self._restore_last_good()
        self.metrics.counter(
            "learner_nonfinite_total", restored=restored).inc()
        if self.tracer.enabled:
            self.tracer.instant(
                "learner_nonfinite", pid="train", tid="learner",
                lag=item.lag, restored=restored,
                step=self._learner_steps)

    def train_phase(self) -> List[RLVRPhaseLog]:
        """One train phase: consume N queue items, publish after each.

        Production is lazy: the forward_n regime refills the queue (N
        fresh minibatches from the newest policy) whenever it runs dry,
        which reproduces the legacy generate-N-then-train-N schedule
        exactly when nothing is dropped.
        """
        hp = self.hp
        if not self._regime_started:
            self.regime.start()
            self._regime_started = True
        if hp.finiteness_guard and self._last_good is None:
            self._last_good = self.state
        logs: List[RLVRPhaseLog] = []
        ctrl = self.controller
        self._phase_consumed = 0
        for _ in range(hp.n_minibatches):
            item = self.regime.next_item(
                self.store.version, timeout=hp.get_timeout,
                max_refills=hp.max_refills)
            if item is None:
                break  # producer stopped / everything dropped
            self._phase_consumed += 1
            mb: RLVRMinibatch = item.payload
            adv = group_advantages(
                mb.rewards, hp.completions_per_prompt)
            adv = adv * jnp.float32(item.weight)
            # Controller loss hook: an optional [B, S] per-token
            # multiplier on the advantage.  The default controller
            # returns None, leaving the fused 1-D-advantage update —
            # and its jit cache — byte-identical to the legacy path.
            log_pi = None
            if ctrl.needs_log_pi:
                log_pi = np.asarray(self._score_log_pi(
                    self.state.params, mb.gen.tokens))
            token_w = ctrl.loss_weights(
                item,
                advantages=np.asarray(adv),
                log_beta=np.asarray(mb.gen.log_beta),
                mask=np.asarray(mb.gen.mask),
                log_pi=log_pi,
            )
            adv_in = (adv if token_w is None
                      else adv[:, None] * jnp.asarray(token_w, jnp.float32))
            t0 = time.monotonic()
            with self.tracer.span("learner_step", pid="train", tid="learner",
                                  lag=item.lag, weight=float(item.weight)):
                if ctrl.needs_gradients:
                    grads, aux = self._grad_step(
                        self.state, mb.gen.tokens, mb.gen.log_beta,
                        mb.gen.mask, adv_in)
                    grads, grad_info = ctrl.transform_gradients(item, grads)
                    self.state, gnorm = self._apply_step(self.state, grads)
                    aux = {k: jax.device_get(v) for k, v in aux.items()}
                    aux["grad_norm"] = jax.device_get(gnorm)
                    aux.update(grad_info)
                else:
                    self.state, aux = self._update(
                        self.state, mb.gen.tokens, mb.gen.log_beta,
                        mb.gen.mask, adv_in)
                    aux = {k: jax.device_get(v) for k, v in aux.items()}
            self._h_step.observe(time.monotonic() - t0)
            self._learner_steps += 1
            if self.injector.active:
                poisoned_params, poisoned = self.injector.poison(
                    "learner_step", self.state.params,
                    at_step=self._learner_steps)
                if poisoned:
                    self.state = self.state._replace(
                        params=poisoned_params)
            if hp.finiteness_guard and not self._step_finite(aux):
                # Divergence firewall: drop this update, restore the
                # last finite state, and keep training — the bad step
                # is never published, so generation can't see it.
                self._skip_nonfinite(item)
                continue
            self._last_good = self.state
            if hp.guard_checkpoint_dir:
                save_checkpoint(
                    hp.guard_checkpoint_dir,
                    int(jax.device_get(self.state.updates)),
                    {"params": self.state.params,
                     "opt_state": self.state.opt_state},
                    meta={"source": "finiteness_guard"})
            ctrl.on_learner_step(item, aux)
            self.store.publish(self.state.params)
            frac = aux.get("frac_filtered", aux.get("clip_frac", 0.0))
            logs.append(RLVRPhaseLog(
                mean_reward=float(jnp.mean(mb.rewards)),
                tv=float(aux["tv"]),
                frac_filtered=float(frac),
                filter_active=float(aux.get("filter_active", 1.0)),
                staleness=item.lag,
                weight=float(item.weight),
            ))
        return logs

    def evaluate(self, n: Optional[int] = 256) -> float:
        return self.generator.eval_accuracy(self.state.params, n)

    def train(self, phases: int, eval_every: int = 5) -> RLVRResult:
        accs: List[float] = []
        logs: List[RLVRPhaseLog] = []
        try:
            for i in range(phases):
                phase_logs = self.train_phase()
                logs.extend(phase_logs)
                if not phase_logs:
                    break  # end of stream: no point re-evaluating
                if (i + 1) % eval_every == 0 or i == phases - 1:
                    accs.append(self.evaluate())
                if self._phase_consumed < self.hp.n_minibatches:
                    # Starved mid-phase (producer done / all-drop).
                    # Keyed off items *consumed*, not updates logged: a
                    # finiteness-guard skip shortens the logs but is not
                    # starvation — training must keep going.
                    break
        finally:
            if not self.regime.phase_locked:
                self.close()
        return RLVRResult(
            eval_accuracy=accs, phase_logs=logs,
            runtime_stats=collect_runtime_stats(self.store, self.queue),
        )
