"""End-to-end simulated-async RL runner (one seed x env x algorithm x K).

Composes:  SimulatedAsyncActors (policy-buffer mixture, Fig. 1 left)
        -> make_train_phase (algorithm update)
        -> evaluate_policy (post-phase deterministic return, §5.1 protocol)

The paper runs 500 envs x 1000 steps x 100M total steps x 10 seeds on
MuJoCo; the CPU-scaled defaults (configurable) keep the identical protocol
at ~1-2 orders of magnitude smaller so the full Fig. 3/4 grid finishes in
minutes inside `benchmarks/`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs import make_env, wrap_autoreset
from repro.models.mlp_policy import act, mlp_policy_init, policy_dist
from repro.rollout.async_engine import SimulatedAsyncActors
from repro.rollout.env_rollout import evaluate_policy
from repro.train.trainer_rl import (
    RLHyperparams,
    init_train_state,
    make_train_phase,
)


@dataclass
class AsyncRLRunConfig:
    env_name: str = "pendulum"
    algorithm: str = "vaco"
    buffer_capacity: int = 1          # degree of asynchronicity (K)
    n_actors: int = 32                # paper: 500
    rollout_steps: int = 128          # paper: 1000
    total_phases: int = 30
    eval_episodes: int = 16
    seed: int = 0
    hp: RLHyperparams = field(default_factory=RLHyperparams)


@dataclass
class AsyncRLResult:
    returns: List[float]              # eval return after each phase
    metrics: List[Dict[str, float]]
    final_tv: float


def run_async_rl(cfg: AsyncRLRunConfig) -> AsyncRLResult:
    overrides = {"algorithm": cfg.algorithm,
                 "total_phases": cfg.total_phases}
    if cfg.algorithm == "ppo_kl" and cfg.hp.kl_coef == 0.0:
        overrides["kl_coef"] = 1.0   # "PPO-KL Penalty=1" (Fig. 3)
    hp = RLHyperparams(**{**cfg.hp.__dict__, **overrides})
    env = wrap_autoreset(make_env(cfg.env_name))
    key = jax.random.PRNGKey(cfg.seed)
    k_init, k_actors, key = jax.random.split(key, 3)

    params = mlp_policy_init(k_init, env.obs_dim, env.act_dim)
    state = init_train_state(params)
    actors = SimulatedAsyncActors(
        env, act, params,
        n_actors=cfg.n_actors,
        buffer_capacity=cfg.buffer_capacity,
        rollout_steps=cfg.rollout_steps,
        seed=cfg.seed + 1,
    )
    train_phase = make_train_phase(hp)

    def det_policy(p, obs):
        return policy_dist(p, obs).mean

    eval_fn = jax.jit(
        lambda p, k: evaluate_policy(env, det_policy, p, k,
                                     cfg.eval_episodes)
    )

    returns: List[float] = []
    metric_log: List[Dict[str, float]] = []
    final_tv = 0.0
    for phase in range(cfg.total_phases):
        batch, _slots = actors.collect()
        key, k_train, k_eval = jax.random.split(key, 3)
        state, metrics = train_phase(state, batch, k_train)
        actors.push_policy(state.params)
        ret = float(eval_fn(state.params, k_eval))
        returns.append(ret)
        m = {k: float(v) for k, v in metrics.items()}
        metric_log.append(m)
        final_tv = m.get("final_tv", 0.0)
    return AsyncRLResult(returns=returns, metrics=metric_log,
                         final_tv=final_tv)


def run_grid(
    env_names: List[str],
    algorithms: List[str],
    buffer_capacities: List[int],
    seeds: List[int],
    **run_kwargs,
) -> Dict[str, Dict[int, np.ndarray]]:
    """Fig. 3-style grid. Returns {alg: {K: scores [envs, seeds]}} of final
    returns (mean of last 3 eval points for stability)."""
    out: Dict[str, Dict[int, np.ndarray]] = {}
    for alg in algorithms:
        out[alg] = {}
        for cap in buffer_capacities:
            scores = np.zeros((len(env_names), len(seeds)))
            for i, env_name in enumerate(env_names):
                for j, seed in enumerate(seeds):
                    res = run_async_rl(AsyncRLRunConfig(
                        env_name=env_name, algorithm=alg,
                        buffer_capacity=cap, seed=seed, **run_kwargs,
                    ))
                    scores[i, j] = float(np.mean(res.returns[-3:]))
            out[alg][cap] = scores
    return out
