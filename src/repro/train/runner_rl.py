"""End-to-end async RL runner (one seed x env x algorithm x regime).

Composes the unified actor-learner runtime:

    PolicyStore (versioned snapshot ring)
      -> lag regime producer (backward_mixture | forward_n | threaded)
      -> TrajectoryQueue (staleness tags + admission control)
      -> make_train_phase (algorithm update)
      -> store.publish (new version)
      -> evaluate_policy (post-phase deterministic return, §5.1 protocol)

``backward_mixture`` reproduces the paper's Fig. 1-left protocol (and the
legacy ``SimulatedAsyncActors`` numerics bit-for-bit); ``forward_n`` runs
the generate-N/train-N schedule on env rollouts; ``threaded`` runs a real
producer thread against the consuming learner — the repo's first
genuinely asynchronous execution mode.

The paper runs 500 envs x 1000 steps x 100M total steps x 10 seeds on
MuJoCo; the CPU-scaled defaults (configurable) keep the identical
protocol at ~1-2 orders of magnitude smaller so the full Fig. 3/4 grid
finishes in minutes inside `benchmarks/`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tv_filter import tv_estimate
from repro.envs import make_env, wrap_autoreset
from repro.metrics.runtime_metrics import collect_runtime_stats
from repro.models.mlp_policy import act, mlp_policy_init, policy_dist
from repro.rollout.env_rollout import evaluate_policy
from repro.runtime import (
    FrozenRolloutProducer,
    MixtureRolloutProducer,
    PolicyStore,
    TrajectoryQueue,
    make_controller,
    make_regime,
    parse_controller_spec,
    spec_from_legacy,
)
from repro.train.trainer_rl import (
    RLHyperparams,
    init_train_state,
    make_train_phase,
)


@dataclass
class AsyncRLRunConfig:
    env_name: str = "pendulum"
    algorithm: str = "vaco"
    buffer_capacity: int = 1          # degree of asynchronicity (K)
    n_actors: int = 32                # paper: 500
    rollout_steps: int = 128          # paper: 1000
    total_phases: int = 30
    eval_episodes: int = 16
    seed: int = 0
    hp: RLHyperparams = field(default_factory=RLHyperparams)
    # --- runtime ---
    runtime: str = "backward_mixture"  # backward_mixture|forward_n|threaded
    forward_n: int = 4                 # items per frozen policy (forward_n)
    queue_maxsize: int = 4             # producer backpressure (threaded)
    # Lag controller, "name:key=val,..." (see runtime.controllers); wins
    # over the deprecated string-keyed fields below when set.
    controller: Optional[str] = None
    admission: str = "pass_through"    # deprecated: use controller=
    max_lag: int = 4                   # deprecated: use controller=
    admission_delta: Optional[float] = None  # deprecated: use controller=
    admission_mode: str = "drop"       # deprecated: use controller=
    get_timeout: float = 120.0         # learner wait per item (threaded)
    tracer: Any = None                 # obs.Tracer (None = no tracing)


@dataclass
class AsyncRLResult:
    returns: List[float]              # eval return after each phase
    metrics: List[Dict[str, float]]
    final_tv: float
    runtime_stats: Dict[str, Any] = field(default_factory=dict)


def _make_tv_fn(store: PolicyStore):
    """Trajectory-level TV estimate vs the *current* policy (Eq. 8)."""

    @jax.jit
    def _tv(params, obs, actions, log_beta):
        log_pi = policy_dist(params, obs).log_prob(actions)
        return tv_estimate(log_pi - log_beta)

    def tv_fn(batch) -> float:
        params, _ = store.latest()
        return float(_tv(params, batch.obs, batch.actions, batch.log_beta))

    return tv_fn


def run_async_rl(cfg: AsyncRLRunConfig) -> AsyncRLResult:
    overrides = {"algorithm": cfg.algorithm,
                 "total_phases": cfg.total_phases}
    if cfg.algorithm == "ppo_kl" and cfg.hp.kl_coef == 0.0:
        overrides["kl_coef"] = 1.0   # "PPO-KL Penalty=1" (Fig. 3)
    hp = RLHyperparams(**{**cfg.hp.__dict__, **overrides})
    env = wrap_autoreset(make_env(cfg.env_name))
    key = jax.random.PRNGKey(cfg.seed)
    k_init, k_actors, key = jax.random.split(key, 3)

    params = mlp_policy_init(k_init, env.obs_dim, env.act_dim)
    state = init_train_state(params)
    train_phase = make_train_phase(hp)

    # --- runtime assembly ---------------------------------------------------
    from repro.obs.tracer import NULL_TRACER

    tracer = cfg.tracer if cfg.tracer is not None else NULL_TRACER
    store = PolicyStore(params, capacity=cfg.buffer_capacity,
                        tracer=tracer)
    if cfg.controller is not None:
        spec = parse_controller_spec(cfg.controller)
    else:
        spec = spec_from_legacy(
            cfg.admission,
            max_lag=cfg.max_lag,
            delta=(cfg.admission_delta
                   if cfg.admission_delta is not None else hp.delta),
            mode=cfg.admission_mode,
        )
    admission = make_controller(
        spec,
        tv_fn=_make_tv_fn(store) if spec.name == "tv_gate" else None,
    )
    queue = TrajectoryQueue(
        maxsize=cfg.queue_maxsize if cfg.runtime == "threaded" else 0,
        admission=admission,
        tracer=tracer,
    )
    if cfg.runtime == "backward_mixture":
        producer = MixtureRolloutProducer(
            env, act, n_actors=cfg.n_actors,
            rollout_steps=cfg.rollout_steps, seed=cfg.seed + 1,
        )
    else:
        producer = FrozenRolloutProducer(
            env, act, n_actors=cfg.n_actors,
            rollout_steps=cfg.rollout_steps, seed=cfg.seed + 1,
        )
    # Threaded production is finite: leave headroom over total_phases so
    # admission drops don't starve the learner, which stops on its own.
    regime = make_regime(
        cfg.runtime, store, queue, producer,
        forward_n=cfg.forward_n,
        max_items=4 * cfg.total_phases,
    )

    def det_policy(p, obs):
        return policy_dist(p, obs).mean

    eval_fn = jax.jit(
        lambda p, k: evaluate_policy(env, det_policy, p, k,
                                     cfg.eval_episodes)
    )

    returns: List[float] = []
    metric_log: List[Dict[str, float]] = []
    final_tv = 0.0
    regime.start()
    try:
        phase = 0
        while phase < cfg.total_phases:
            item = regime.next_item(
                store.version, timeout=cfg.get_timeout)
            if item is None:
                break  # producer exhausted / everything dropped
            key, k_train, k_eval = jax.random.split(key, 3)
            with tracer.span("learner_step", pid="train", tid="learner",
                             lag=item.lag, weight=float(item.weight)):
                state, metrics = train_phase(
                    state, item.payload, k_train,
                    weight=jnp.float32(item.weight),
                )
                metrics = jax.device_get(metrics)
            store.publish(state.params)
            ret = float(eval_fn(state.params, k_eval))
            returns.append(ret)
            m = {k: float(v) for k, v in metrics.items()}
            m["policy_lag"] = float(item.lag)
            m["item_weight"] = float(item.weight)
            if item.tv is not None:
                m["admission_tv"] = float(item.tv)
            metric_log.append(m)
            final_tv = m.get("final_tv", 0.0)
            phase += 1
    finally:
        regime.stop()
    return AsyncRLResult(
        returns=returns, metrics=metric_log, final_tv=final_tv,
        runtime_stats=collect_runtime_stats(store, queue),
    )


def run_grid(
    env_names: List[str],
    algorithms: List[str],
    buffer_capacities: List[int],
    seeds: List[int],
    **run_kwargs,
) -> Dict[str, Dict[int, np.ndarray]]:
    """Fig. 3-style grid. Returns {alg: {K: scores [envs, seeds]}} of final
    returns (mean of last 3 eval points for stability)."""
    out: Dict[str, Dict[int, np.ndarray]] = {}
    for alg in algorithms:
        out[alg] = {}
        for cap in buffer_capacities:
            scores = np.zeros((len(env_names), len(seeds)))
            for i, env_name in enumerate(env_names):
                for j, seed in enumerate(seeds):
                    res = run_async_rl(AsyncRLRunConfig(
                        env_name=env_name, algorithm=alg,
                        buffer_capacity=cap, seed=seed, **run_kwargs,
                    ))
                    scores[i, j] = float(np.mean(res.returns[-3:]))
            out[alg][cap] = scores
    return out
