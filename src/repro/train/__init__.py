from repro.train.trainer_rl import (
    RLHyperparams,
    RLTrainState,
    init_train_state,
    make_train_phase,
)
from repro.train.runner_rl import (
    AsyncRLRunConfig,
    AsyncRLResult,
    run_async_rl,
    run_grid,
)
from repro.train.trainer_rlvr import (
    RLVRHyperparams,
    RLVRTrainer,
    RLVRResult,
)

__all__ = [
    "RLHyperparams",
    "RLTrainState",
    "init_train_state",
    "make_train_phase",
    "AsyncRLRunConfig",
    "AsyncRLResult",
    "run_async_rl",
    "run_grid",
    "RLVRHyperparams",
    "RLVRTrainer",
    "RLVRResult",
]
