"""Policy distributions.

Two families cover the paper's experiments:

* ``DiagGaussian`` — continuous control (the MuJoCo-analog setup of §5.1).
  State-independent log-std parameters, tanh-free (CleanRL convention).
* ``Categorical`` — token policies for the RLVR setup of §5.2 and for the
  exact tabular-MDP theory tests.

Both expose log_prob / sample / entropy and an *analytic* per-state total
variation for the tabular/diagnostic paths.  The training-path TV estimate
(Eq. 8 of the paper) lives in ``repro.core.tv_filter`` and only needs
log-probs, so it is distribution-agnostic.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

_LOG_2PI = math.log(2.0 * math.pi)


class DiagGaussian(NamedTuple):
    """Diagonal Gaussian with mean [.., D] and log_std [.., D]."""

    mean: jax.Array
    log_std: jax.Array

    def sample(self, key: jax.Array) -> jax.Array:
        eps = jax.random.normal(key, self.mean.shape, self.mean.dtype)
        return self.mean + jnp.exp(self.log_std) * eps

    def log_prob(self, a: jax.Array) -> jax.Array:
        """Sum over the trailing action dimension."""
        z = (a - self.mean) * jnp.exp(-self.log_std)
        lp = -0.5 * (z * z + _LOG_2PI) - self.log_std
        return jnp.sum(lp, axis=-1)

    def entropy(self) -> jax.Array:
        return jnp.sum(self.log_std + 0.5 * (_LOG_2PI + 1.0), axis=-1)

    def kl(self, other: "DiagGaussian") -> jax.Array:
        """KL(self || other), summed over action dims."""
        var_ratio = jnp.exp(2.0 * (self.log_std - other.log_std))
        t1 = (self.mean - other.mean) * jnp.exp(-other.log_std)
        kl = 0.5 * (var_ratio + t1 * t1 - 1.0) + (other.log_std - self.log_std)
        return jnp.sum(kl, axis=-1)


class Categorical(NamedTuple):
    """Categorical over the trailing axis of `logits`."""

    logits: jax.Array

    @property
    def log_probs(self) -> jax.Array:
        return jax.nn.log_softmax(self.logits, axis=-1)

    def sample(self, key: jax.Array) -> jax.Array:
        return jax.random.categorical(key, self.logits, axis=-1)

    def log_prob(self, a: jax.Array) -> jax.Array:
        lp = self.log_probs
        return jnp.take_along_axis(lp, a[..., None], axis=-1)[..., 0]

    def entropy(self) -> jax.Array:
        lp = self.log_probs
        p = jnp.exp(lp)
        return -jnp.sum(p * lp, axis=-1)

    def kl(self, other: "Categorical") -> jax.Array:
        lp, lq = self.log_probs, other.log_probs
        return jnp.sum(jnp.exp(lp) * (lp - lq), axis=-1)

    def tv(self, other: "Categorical") -> jax.Array:
        """Exact per-state D_TV = (1/2) sum_a |p(a) - q(a)| (paper Thm 3.2)."""
        p = jnp.exp(self.log_probs)
        q = jnp.exp(other.log_probs)
        return 0.5 * jnp.sum(jnp.abs(p - q), axis=-1)
