"""Generalized Advantage Estimation (Schulman et al., 2015b).

Used by the PPO / PPO-KL / SPO baselines.  Note the identity exercised in
tests/test_vtrace.py: with on-policy data (log_ratios == 0) and
rho_bar = c_bar = inf, V-trace's correction reduces exactly to GAE.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class GAEOutput(NamedTuple):
    advantages: jax.Array  # [B, T]
    returns: jax.Array     # [B, T]  advantages + values (value targets)


def gae(
    *,
    values: jax.Array,           # [B, T]
    bootstrap_value: jax.Array,  # [B]
    rewards: jax.Array,          # [B, T]
    discounts: jax.Array,        # [B, T] gamma * (1 - done)
    lam: float = 0.95,
) -> GAEOutput:
    values_tp1 = jnp.concatenate(
        [values[:, 1:], bootstrap_value[:, None]], axis=1
    )
    deltas = rewards + discounts * values_tp1 - values

    def step(acc, x):
        delta_t, disc_t = x
        acc = delta_t + disc_t * lam * acc
        return acc, acc

    _, adv = jax.lax.scan(
        step,
        jnp.zeros_like(bootstrap_value),
        (deltas.T, discounts.T),
        reverse=True,
    )
    advantages = adv.T
    return GAEOutput(advantages=advantages, returns=advantages + values)


def normalize_advantages(adv: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Batch-standardized advantages (CleanRL default for PPO)."""
    return (adv - jnp.mean(adv)) / (jnp.std(adv) + eps)
