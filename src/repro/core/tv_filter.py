"""TV-divergence estimation and gradient filtering — the paper's Eq. 8/19.

The sampled estimate of the expected total variation between the current
policy pi_theta and the behavior policy beta_T (Eq. 8):

    E_{s~d^beta}[D_TV(pi||beta)[s]]  ~=  (1/2N) sum_i |ratio_i - 1|,
    ratio_i = pi_theta(a_i|s_i) / beta_T(a_i|s_i).

The filter (Eq. 19 / Algorithm 1): when the minibatch estimate exceeds the
threshold delta/2, detach the gradient of every sample whose gradient
direction would *increase* D_TV, i.e. samples with

    (A(s_i,a_i) - c_H) * sgn(pi_theta(a_i|s_i) - beta_T(a_i|s_i)) > 0.

Detaching (stop_gradient on the ratio) rather than dropping keeps the loss
value intact while zeroing the sample's contribution to the update — the
paper's "Detach Gradient pi_theta(a_t|s_t)" step.

Masked samples may be *re-admitted* on later epochs if the TV estimate
falls back below the threshold: the controller interpretation in §4.2.2.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def tv_estimate(
    log_ratios: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """(1/2N) sum |exp(log_ratio) - 1| over valid entries (Eq. 8)."""
    tv = 0.5 * jnp.abs(jnp.exp(log_ratios) - 1.0)
    if mask is None:
        return jnp.mean(tv)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(tv * mask) / denom


class FilterResult(NamedTuple):
    detach_mask: jax.Array   # [N] 1.0 where the sample's gradient is detached
    tv: jax.Array            # scalar minibatch TV estimate
    active: jax.Array        # scalar bool: was the filter triggered?
    frac_filtered: jax.Array  # scalar in [0,1]: fraction of batch detached


def tv_filter_mask(
    *,
    log_ratios: jax.Array,     # [N] log(pi_theta / beta_T), current policy
    advantages: jax.Array,     # [N] A_{pi_T} (realigned; fixed per phase)
    delta: float,
    entropy_coef: float = 0.0,
    valid_mask: jax.Array | None = None,
) -> FilterResult:
    """Compute the VACO detach mask for one minibatch (Algorithm 1).

    The mask is 1 where (A - c_H) * sgn(ratio - 1) > 0 *and* the minibatch
    TV estimate exceeds delta/2.  sgn(pi - beta) == sgn(ratio - 1) because
    beta > 0.
    """
    tv = tv_estimate(log_ratios, valid_mask)
    active = tv > (delta / 2.0)
    increases_tv = (
        (advantages - entropy_coef) * jnp.sign(jnp.expm1(log_ratios))
    ) > 0.0
    detach = jnp.where(active, increases_tv.astype(log_ratios.dtype), 0.0)
    if valid_mask is not None:
        detach = detach * valid_mask
        denom = jnp.maximum(jnp.sum(valid_mask), 1.0)
    else:
        denom = jnp.asarray(log_ratios.size, log_ratios.dtype)
    frac = jnp.sum(detach) / denom
    return FilterResult(
        detach_mask=detach, tv=tv, active=active, frac_filtered=frac
    )


def apply_detach(log_ratios: jax.Array, detach_mask: jax.Array) -> jax.Array:
    """Replace masked entries' log-ratios by stop_gradient'ed copies.

    The loss value is unchanged; only gradients of detached samples vanish.
    """
    return jnp.where(
        detach_mask > 0.0,
        jax.lax.stop_gradient(log_ratios),
        log_ratios,
    )


def exact_tv_decrease_check(
    log_ratios: jax.Array,
    advantages: jax.Array,
    entropy_coef: float = 0.0,
) -> jax.Array:
    """Sign agreement between the loss-gradient and TV-gradient directions.

    From Eqs. 17-18: per sample, d(loss)/d(logit) ∝ ratio * (A - c_H) and
    d(TV)/d(logit) ∝ ratio * sgn(ratio - 1).  A sample pushes TV *up* iff
    the product of the non-ratio factors is positive.  Returns that product
    (tests assert the filter removes exactly the positive entries).
    """
    return (advantages - entropy_coef) * jnp.sign(jnp.expm1(log_ratios))
