"""Simulated asynchronous RL: the policy-buffer mixture of Fig. 1 (left).

The paper controls *backward* policy lag by keeping a FIFO buffer of the
last K policies; at the start of each collection phase every actor samples
a policy uniformly from the buffer, so the behavior policy is the episodic
mixture beta_T(a|s) = E_{i~M}[pi_i(a|s)] of Eq. 1.  K = 1 recovers fully
synchronous on-policy collection; larger K = more backward lag.

*Forward* policy lag (§5.2) is a property of the update schedule, not the
buffer: generate N minibatches from one frozen policy, then take N
updates.  ``ForwardLagSchedule`` captures that protocol for the RLVR
trainer.

Everything here is jit-compatible: the buffer is a stacked pytree with a
leading capacity axis, and sampling gathers per-actor parameter trees that
can be consumed by a vmapped rollout.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class PolicyBuffer(NamedTuple):
    stacked: Any        # pytree; every leaf has leading dim = capacity
    head: jax.Array     # scalar int32 — next write position
    count: jax.Array    # scalar int32 — number of valid entries (<= capacity)

    @property
    def capacity(self) -> int:
        return jax.tree.leaves(self.stacked)[0].shape[0]


def buffer_init(params: Any, capacity: int) -> PolicyBuffer:
    """Start the buffer with `capacity` copies of the initial policy."""
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (capacity,) + x.shape).copy(),
        params,
    )
    return PolicyBuffer(
        stacked=stacked,
        head=jnp.zeros((), jnp.int32),
        count=jnp.ones((), jnp.int32),  # the initial policy is valid
    )


def buffer_push(buf: PolicyBuffer, params: Any) -> PolicyBuffer:
    """FIFO insert of a new policy snapshot."""
    stacked = jax.tree.map(
        lambda s, p: jax.lax.dynamic_update_index_in_dim(s, p, buf.head, 0),
        buf.stacked,
        params,
    )
    cap = buf.capacity
    return PolicyBuffer(
        stacked=stacked,
        head=(buf.head + 1) % cap,
        count=jnp.minimum(buf.count + 1, cap),
    )


def buffer_sample(buf: PolicyBuffer, key: jax.Array, n_actors: int):
    """Uniformly sample `n_actors` policies from the valid buffer entries.

    Returns (params_batched, indices): every leaf of `params_batched` has a
    leading dim of n_actors, suitable for `jax.vmap(policy_apply)`.
    """
    idx = jax.random.randint(key, (n_actors,), 0, buf.count)
    # Ring-buffer order: entry j (age order) lives at (head - count + j) % cap
    cap = buf.capacity
    slots = (buf.head - buf.count + idx) % cap
    params_batched = jax.tree.map(lambda s: s[slots], buf.stacked)
    return params_batched, slots


def buffer_latest(buf: PolicyBuffer) -> Any:
    """The most recently pushed policy (== the learner's pi_T)."""
    cap = buf.capacity
    slot = (buf.head - 1) % cap
    return jax.tree.map(lambda s: s[slot], buf.stacked)


class ForwardLagSchedule(NamedTuple):
    """§5.2 protocol: N minibatches generated per frozen behavior policy.

    The k-th update within a phase (k = 0..n_minibatches-1) trains on data
    whose behavior policy is k steps stale — by the last minibatch the
    learner is N-1 updates ahead of its data.
    """

    n_minibatches: int

    def lag_at(self, update_in_phase: int) -> int:
        return update_in_phase  # staleness grows linearly within the phase


def mixture_log_prob(
    log_probs_per_policy: jax.Array, axis: int = 0
) -> jax.Array:
    """log beta for an equal-weight mixture: logsumexp over policies - log K.

    Used by diagnostics that compare the *true* mixture density (Eq. 1)
    against the per-actor densities actually recorded in the rollouts.
    """
    k = log_probs_per_policy.shape[axis]
    return jax.nn.logsumexp(log_probs_per_policy, axis=axis) - jnp.log(
        float(k)
    )
