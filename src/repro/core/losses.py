"""Policy-optimization losses: VACO and the paper's comparison baselines.

Every loss follows the same convention so the trainers and the RLVR stack
can swap algorithms behind one interface:

    loss_fn(log_pi, log_beta, advantages, ..., valid_mask) -> (loss, aux)

* ``log_pi``      — log pi_theta(a|s) under the *current* parameters
                    (differentiable).
* ``log_beta``    — log beta_T(a|s) recorded at collection time (constant).
* ``advantages``  — whatever estimator the algorithm prescribes:
                    A_vtrace w.r.t. pi_T for VACO (realigned, fixed per
                    phase), GAE for PPO/SPO, per-update V-trace for IMPALA,
                    group-normalized MC returns for GRPO.
* ``valid_mask``  — optional {0,1} mask (token padding / truncated steps).

Shapes are arbitrary ([N] for classic RL, [B, S] per-token for RLVR); all
reductions are masked means.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.tv_filter import apply_detach, tv_estimate, tv_filter_mask


def _masked_mean(x: jax.Array, mask: jax.Array | None) -> jax.Array:
    if mask is None:
        return jnp.mean(x)
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# VACO (the paper's contribution — Algorithm 1)
# ---------------------------------------------------------------------------


class VACOConfig(NamedTuple):
    delta: float = 0.2          # TV threshold (constraint is delta/2)
    entropy_coef: float = 0.0   # c_H — max-entropy term inside the ratio
    value_coef: float = 0.5     # c_v
    policy_coef: float = 1.0    # c_pi


def vaco_policy_loss(
    *,
    log_pi: jax.Array,
    log_beta: jax.Array,
    advantages: jax.Array,
    cfg: VACOConfig,
    valid_mask: jax.Array | None = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """TV-filtered importance-weighted policy loss (Algorithm 1).

    L_pi = -(1/N) sum_i ratio_i * (A_i - c_H * log pi_theta(a_i|s_i))
    with ratio_i detached on samples the TV filter removes.  The advantage
    is A_vtrace w.r.t. pi_T, *stop-gradient'ed by the caller* (realignment
    happens once per phase, outside this loss).
    """
    advantages = jax.lax.stop_gradient(advantages)
    log_ratios = log_pi - jax.lax.stop_gradient(log_beta)

    flt = tv_filter_mask(
        log_ratios=jax.lax.stop_gradient(log_ratios),
        advantages=advantages,
        delta=cfg.delta,
        entropy_coef=cfg.entropy_coef,
        valid_mask=valid_mask,
    )
    filtered_log_ratios = apply_detach(log_ratios, flt.detach_mask)
    ratios = jnp.exp(filtered_log_ratios)

    # Entropy enters through the same importance weight (Eq. 20-21): the
    # per-sample integrand is ratio * (A - c_H * log pi).
    per_sample = ratios * (advantages - cfg.entropy_coef * log_pi)
    loss = -_masked_mean(per_sample, valid_mask)

    aux = {
        "tv": flt.tv,
        "filter_active": flt.active.astype(jnp.float32),
        "frac_filtered": flt.frac_filtered,
        "mean_ratio": _masked_mean(jnp.exp(log_ratios), valid_mask),
    }
    return loss, aux


def value_loss_mse(
    values: jax.Array,
    targets: jax.Array,
    valid_mask: jax.Array | None = None,
) -> jax.Array:
    """0.5 * mean (V_phi(s) - v_target)^2 (Algorithm 1's L_v)."""
    targets = jax.lax.stop_gradient(targets)
    return 0.5 * _masked_mean(jnp.square(values - targets), valid_mask)


def vaco_total_loss(
    *,
    log_pi: jax.Array,
    log_beta: jax.Array,
    advantages: jax.Array,
    values: jax.Array,
    value_targets: jax.Array,
    cfg: VACOConfig,
    valid_mask: jax.Array | None = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    l_pi, aux = vaco_policy_loss(
        log_pi=log_pi,
        log_beta=log_beta,
        advantages=advantages,
        cfg=cfg,
        valid_mask=valid_mask,
    )
    l_v = value_loss_mse(values, value_targets, valid_mask)
    loss = cfg.policy_coef * l_pi + cfg.value_coef * l_v
    aux = dict(aux, policy_loss=l_pi, value_loss=l_v, total_loss=loss)
    return loss, aux


# ---------------------------------------------------------------------------
# PPO (clip + optional KL penalty) — Schulman et al. 2017
# ---------------------------------------------------------------------------


class PPOConfig(NamedTuple):
    clip_low: float = 0.2        # ratio clipped to [1-clip_low, 1+clip_high]
    clip_high: float = 0.2       # DAPO-style asymmetric clipping supported
    kl_coef: float = 0.0         # "PPO-KL Penalty=k" baselines of Fig. 3
    entropy_coef: float = 0.0
    value_coef: float = 0.5
    clip_value: bool = False
    value_clip_eps: float = 0.2


def ppo_policy_loss(
    *,
    log_pi: jax.Array,
    log_beta: jax.Array,
    advantages: jax.Array,
    cfg: PPOConfig,
    valid_mask: jax.Array | None = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    advantages = jax.lax.stop_gradient(advantages)
    log_ratios = log_pi - jax.lax.stop_gradient(log_beta)
    ratios = jnp.exp(log_ratios)
    clipped = jnp.clip(ratios, 1.0 - cfg.clip_low, 1.0 + cfg.clip_high)
    surrogate = jnp.minimum(ratios * advantages, clipped * advantages)
    loss = -_masked_mean(surrogate, valid_mask)

    # k3 estimator of KL(beta || pi): E[exp(-lr) - 1 + lr] >= 0.
    approx_kl = _masked_mean(
        jnp.expm1(-log_ratios) + log_ratios, valid_mask
    )
    if cfg.kl_coef > 0.0:
        loss = loss + cfg.kl_coef * approx_kl

    clip_frac = _masked_mean(
        (jnp.abs(ratios - 1.0) > jnp.where(
            ratios > 1.0, cfg.clip_high, cfg.clip_low
        )).astype(jnp.float32),
        valid_mask,
    )
    aux = {
        "approx_kl": approx_kl,
        "clip_frac": clip_frac,
        "tv": tv_estimate(jax.lax.stop_gradient(log_ratios), valid_mask),
        "mean_ratio": _masked_mean(ratios, valid_mask),
    }
    return loss, aux


def ppo_total_loss(
    *,
    log_pi: jax.Array,
    log_beta: jax.Array,
    advantages: jax.Array,
    values: jax.Array,
    value_targets: jax.Array,
    entropy: jax.Array,
    cfg: PPOConfig,
    old_values: jax.Array | None = None,
    valid_mask: jax.Array | None = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    l_pi, aux = ppo_policy_loss(
        log_pi=log_pi,
        log_beta=log_beta,
        advantages=advantages,
        cfg=cfg,
        valid_mask=valid_mask,
    )
    if cfg.clip_value and old_values is not None:
        v_clipped = old_values + jnp.clip(
            values - old_values, -cfg.value_clip_eps, cfg.value_clip_eps
        )
        l_v = 0.5 * _masked_mean(
            jnp.maximum(
                jnp.square(values - value_targets),
                jnp.square(v_clipped - value_targets),
            ),
            valid_mask,
        )
    else:
        l_v = value_loss_mse(values, value_targets, valid_mask)
    l_ent = _masked_mean(entropy, valid_mask)
    loss = l_pi + cfg.value_coef * l_v - cfg.entropy_coef * l_ent
    aux = dict(
        aux, policy_loss=l_pi, value_loss=l_v, entropy=l_ent, total_loss=loss
    )
    return loss, aux


# ---------------------------------------------------------------------------
# SPO — Simple Policy Optimization (Xie et al., 2025): squared-TV penalty
# ---------------------------------------------------------------------------


class SPOConfig(NamedTuple):
    penalty_coef: float = 20.0   # lambda on E[(ratio - 1)^2]
    entropy_coef: float = 0.0
    value_coef: float = 0.5


def spo_total_loss(
    *,
    log_pi: jax.Array,
    log_beta: jax.Array,
    advantages: jax.Array,
    values: jax.Array,
    value_targets: jax.Array,
    entropy: jax.Array,
    cfg: SPOConfig,
    valid_mask: jax.Array | None = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    advantages = jax.lax.stop_gradient(advantages)
    log_ratios = log_pi - jax.lax.stop_gradient(log_beta)
    ratios = jnp.exp(log_ratios)
    surrogate = ratios * advantages
    penalty = jnp.square(ratios - 1.0)  # squared-TV surrogate, no clip
    l_pi = -_masked_mean(surrogate - cfg.penalty_coef * penalty, valid_mask)
    l_v = value_loss_mse(values, value_targets, valid_mask)
    l_ent = _masked_mean(entropy, valid_mask)
    loss = l_pi + cfg.value_coef * l_v - cfg.entropy_coef * l_ent
    aux = {
        "policy_loss": l_pi,
        "value_loss": l_v,
        "entropy": l_ent,
        "tv": tv_estimate(jax.lax.stop_gradient(log_ratios), valid_mask),
        "penalty": _masked_mean(penalty, valid_mask),
        "total_loss": loss,
    }
    return loss, aux


# ---------------------------------------------------------------------------
# IMPALA — per-update V-trace actor-critic (Espeholt et al., 2018)
# ---------------------------------------------------------------------------


class IMPALAConfig(NamedTuple):
    entropy_coef: float = 0.0
    value_coef: float = 0.5
    rho_bar_pg: float = 1.0


def impala_total_loss(
    *,
    log_pi: jax.Array,
    log_beta: jax.Array,
    pg_advantages: jax.Array,   # rho_t * (r + gamma v_{t+1} - V), re-estimated
    values: jax.Array,
    value_targets: jax.Array,   # vs from the per-update V-trace pass
    entropy: jax.Array,
    cfg: IMPALAConfig,
    valid_mask: jax.Array | None = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    pg_advantages = jax.lax.stop_gradient(pg_advantages)
    l_pi = -_masked_mean(log_pi * pg_advantages, valid_mask)
    l_v = value_loss_mse(values, value_targets, valid_mask)
    l_ent = _masked_mean(entropy, valid_mask)
    loss = l_pi + cfg.value_coef * l_v - cfg.entropy_coef * l_ent
    log_ratios = log_pi - jax.lax.stop_gradient(log_beta)
    aux = {
        "policy_loss": l_pi,
        "value_loss": l_v,
        "entropy": l_ent,
        "tv": tv_estimate(jax.lax.stop_gradient(log_ratios), valid_mask),
        "total_loss": loss,
    }
    return loss, aux


# ---------------------------------------------------------------------------
# GRPO (Shao et al., 2024) and GRPO+VACO — the §5.2 RLVR losses
# ---------------------------------------------------------------------------


class GRPOConfig(NamedTuple):
    clip_low: float = 0.2
    clip_high: float = 0.272     # DAPO clip-higher (Yu et al., 2025)
    use_vaco: bool = False       # swap clipping for TV filtering
    delta: float = 0.05          # TV threshold in the RLVR setup (Table 2)
    entropy_coef: float = 0.0


def grpo_token_loss(
    *,
    log_pi: jax.Array,        # [B, S] per-token logprobs, current policy
    log_beta: jax.Array,      # [B, S] per-token logprobs at generation time
    advantages: jax.Array,    # [B] or [B, S] group-normalized advantages
    token_mask: jax.Array,    # [B, S] 1 on completion tokens
    cfg: GRPOConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Token-level GRPO loss; PPO-clip or VACO-filter variants.

    The advantage realignment ratio is 1 in this setup (no backward lag;
    paper App. C.2) — the behavioral correction shows up only through the
    ratio in the surrogate, exactly as the paper runs it.
    """
    if advantages.ndim == 1:
        advantages = advantages[:, None] * jnp.ones_like(log_pi)
    advantages = jax.lax.stop_gradient(advantages)

    if cfg.use_vaco:
        vcfg = VACOConfig(delta=cfg.delta, entropy_coef=cfg.entropy_coef)
        return vaco_policy_loss(
            log_pi=log_pi,
            log_beta=log_beta,
            advantages=advantages,
            cfg=vcfg,
            valid_mask=token_mask,
        )
    pcfg = PPOConfig(
        clip_low=cfg.clip_low,
        clip_high=cfg.clip_high,
        entropy_coef=cfg.entropy_coef,
    )
    return ppo_policy_loss(
        log_pi=log_pi,
        log_beta=log_beta,
        advantages=advantages,
        cfg=pcfg,
        valid_mask=token_mask,
    )


def group_advantages(
    rewards: jax.Array, group_size: int, eps: float = 1e-6
) -> jax.Array:
    """GRPO Monte-Carlo group advantages: (r - mean_g) / (std_g + eps).

    ``rewards`` is [B] with B = num_prompts * group_size, completions of
    the same prompt contiguous.
    """
    r = rewards.reshape(-1, group_size)
    mean = jnp.mean(r, axis=1, keepdims=True)
    std = jnp.std(r, axis=1, keepdims=True)
    return ((r - mean) / (std + eps)).reshape(-1)


# ---------------------------------------------------------------------------
# TIS — Truncated Importance Sampling (Yao et al., 2025), discussed by the
# paper's App. C.2 as the alternative fix for the serve/train logprob
# mismatch.  Beyond-paper baseline: per-token ratio capped at c_tis, no
# clip window, no filtering.
# ---------------------------------------------------------------------------


class TISConfig(NamedTuple):
    c_tis: float = 2.0          # ratio truncation
    entropy_coef: float = 0.0


def tis_token_loss(
    *,
    log_pi: jax.Array,
    log_beta: jax.Array,
    advantages: jax.Array,
    token_mask: jax.Array,
    cfg: TISConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """-E[min(c, ratio) * A] with the truncation detached (the gradient
    keeps flowing through un-truncated ratios only)."""
    if advantages.ndim == 1:
        advantages = advantages[:, None] * jnp.ones_like(log_pi)
    advantages = jax.lax.stop_gradient(advantages)
    log_ratios = log_pi - jax.lax.stop_gradient(log_beta)
    ratios = jnp.exp(log_ratios)
    truncated = jnp.minimum(ratios, cfg.c_tis)
    loss = -_masked_mean(truncated * advantages, token_mask)
    aux = {
        "tv": tv_estimate(jax.lax.stop_gradient(log_ratios), token_mask),
        "trunc_frac": _masked_mean(
            (ratios > cfg.c_tis).astype(jnp.float32), token_mask),
        "mean_ratio": _masked_mean(ratios, token_mask),
    }
    return loss, aux


ALGORITHMS = ("vaco", "ppo", "ppo_kl", "spo", "impala", "grpo",
              "grpo_vaco", "tis")
