"""The paper's primary contribution: VACO and its analysis tools.

- vtrace        : advantage realignment (Eqs. 13-15) + IMPALA variant
- tv_filter     : TV estimate (Eq. 8) + gradient filter (Eq. 19 / Alg. 1)
- losses        : VACO, PPO(-KL), SPO, IMPALA, GRPO(+VACO) objectives
- gae           : GAE baseline estimator
- policy_lag    : simulated-async policy buffer (Fig. 1) + forward-lag
                  schedule (section 5.2 protocol)
- distributions : DiagGaussian / Categorical policy heads
"""
from repro.core.vtrace import vtrace, naive_vtrace, VTraceOutput
from repro.core.gae import gae, GAEOutput, normalize_advantages
from repro.core.tv_filter import (
    tv_estimate,
    tv_filter_mask,
    apply_detach,
    FilterResult,
)
from repro.core.losses import (
    VACOConfig,
    PPOConfig,
    SPOConfig,
    IMPALAConfig,
    GRPOConfig,
    vaco_total_loss,
    vaco_policy_loss,
    ppo_total_loss,
    spo_total_loss,
    impala_total_loss,
    grpo_token_loss,
    group_advantages,
    TISConfig,
    tis_token_loss,
    ALGORITHMS,
)
from repro.core.policy_lag import (
    PolicyBuffer,
    buffer_init,
    buffer_push,
    buffer_sample,
    buffer_latest,
    ForwardLagSchedule,
)
from repro.core.distributions import DiagGaussian, Categorical
