"""Post-SPMD HLO analysis: collective-byte accounting for the roofline.

``cost_analysis()`` reports FLOPs and HBM bytes but not collective
traffic; we parse the partitioned HLO text (``compiled.as_text()``) and
sum the output-shape bytes of every communication op:

    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute

Bytes are per-participant (the shapes in partitioned HLO are already the
per-device shard shapes), i.e. directly comparable to per-chip link
bandwidth in the collective roofline term.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %ag = bf16[2,512,128]{2,1,0} all-gather(...)
#       ROOT %tuple ... f32[]{} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^a-z]*\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
# tuple-shaped collectives: = (f32[8,128]{...}, f32[8,128]{...}) all-reduce
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def summary(self) -> str:
        parts = [
            f"{k}: n={self.count_by_kind.get(k, 0)} "
            f"bytes={self.bytes_by_kind.get(k, 0):,}"
            for k in _COLLECTIVES
            if self.count_by_kind.get(k, 0)
        ]
        return "; ".join(parts) if parts else "no collectives"


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum per-device output bytes of every collective in the HLO text."""
    stats = CollectiveStats()

    def add(kind: str, nbytes: int):
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1

    for line in hlo_text.splitlines():
        line = line.strip()
        if not any(c in line for c in _COLLECTIVES):
            continue
        if "-start" in line:  # avoid double counting start/done pairs
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            add(kind, _shape_bytes(dtype, dims))
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            nbytes = sum(
                _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(shapes)
            )
            if nbytes:
                add(kind, nbytes)
    return stats
