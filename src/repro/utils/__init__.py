from repro.utils.tree import (
    tree_add,
    tree_scale,
    tree_zeros_like,
    tree_global_norm,
    tree_size,
    tree_interpolate,
)
from repro.utils.prng import PRNGSequence

__all__ = [
    "tree_add",
    "tree_scale",
    "tree_zeros_like",
    "tree_global_norm",
    "tree_size",
    "tree_interpolate",
    "PRNGSequence",
]
