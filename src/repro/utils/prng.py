"""PRNG key management."""
from __future__ import annotations

import jax


class PRNGSequence:
    """An iterator of fresh PRNG keys, for host-side setup code.

    Jitted code should thread keys explicitly; this helper is for
    trainers/launchers that need "one more key" repeatedly.
    """

    def __init__(self, seed_or_key):
        if isinstance(seed_or_key, int):
            self._key = jax.random.PRNGKey(seed_or_key)
        else:
            self._key = seed_or_key

    def __next__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def next(self) -> jax.Array:
        return next(self)

    def take(self, n: int):
        self._key, *subs = jax.random.split(self._key, n + 1)
        return subs
