"""Small pytree utilities used across the framework.

We do not depend on optax/flax (not installed), so these helpers provide
the tree arithmetic the optimizers, policy-buffer mixtures and checkpoint
code need.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    """Element-wise a + b over two matching pytrees."""
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    """Element-wise a - b over two matching pytrees."""
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    """Multiply every leaf of `a` by scalar `s`."""
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_global_norm(a) -> jax.Array:
    """Global L2 norm across all leaves (float32 accumulation)."""
    leaves = jax.tree.leaves(a)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    return jnp.sqrt(sq)


def tree_size(a) -> int:
    """Total number of scalar elements in the pytree (static)."""
    return int(sum(x.size for x in jax.tree.leaves(a)))


def tree_bytes(a) -> int:
    """Total bytes of the pytree's leaves (static)."""
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(a)))


def tree_interpolate(a, b, t):
    """(1 - t) * a + t * b, leafwise. Used by policy-mixture diagnostics."""
    return jax.tree.map(lambda x, y: (1.0 - t) * x + t * y, a, b)


def tree_cast(a, dtype):
    """Cast all floating-point leaves to `dtype` (ints left untouched)."""

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, a)
