"""Staleness-tagged trajectory queue between actors and the learner.

Every item carries its behavior policy version (stamped by the producer)
and the learner version at both enqueue and consume time, so the item's
*lag* — the quantity every loss in the paper conditions on — is an
observable of the queue rather than something trainers reconstruct.

The queue is thread-safe (the ``threaded`` regime runs a real producer
thread against a consuming learner) and applies its admission policy at
the consume boundary: ``get`` keeps popping until a policy-admitted item
surfaces, counting drops/downweights and recording the lag histogram for
``metrics.runtime_metrics``.  A bounded ``maxsize`` gives natural
backpressure on the producer.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.metrics.runtime_metrics import LagHistogram, RuntimeQueueStats
from repro.obs.tracer import NULL_TRACER, Tracer
from repro.resilience.faults import FaultInjector, NULL_INJECTOR
from repro.runtime.admission import AdmissionPolicy, PassThrough


@dataclass
class TrajectoryItem:
    payload: Any
    behavior_version: int
    enqueue_learner_version: int
    learner_version_at_consume: Optional[int] = None
    weight: float = 1.0
    tv: Optional[float] = None
    meta: Dict[str, Any] = field(default_factory=dict)
    # Mixture items (backward_mixture, mid-swap served trajectories) span
    # a range of behavior versions; `behavior_version` is the oldest (the
    # conservative representative), `behavior_version_newest` the newest.
    # None means a single-version item (newest == oldest).
    behavior_version_newest: Optional[int] = None

    @property
    def _ref_version(self) -> int:
        return (
            self.learner_version_at_consume
            if self.learner_version_at_consume is not None
            else self.enqueue_learner_version
        )

    @property
    def lag(self) -> int:
        """Learner updates between the *oldest* behavior policy any token
        was sampled from and (consume) use."""
        return self._ref_version - self.behavior_version

    @property
    def lag_oldest(self) -> int:
        """Alias of :attr:`lag`: the item's worst-case staleness."""
        return self.lag

    @property
    def lag_newest(self) -> int:
        """Staleness of the freshest behavior version in the item."""
        newest = (
            self.behavior_version_newest
            if self.behavior_version_newest is not None
            else self.behavior_version
        )
        return self._ref_version - newest


class QueueClosed(RuntimeError):
    """put() after close() — the learner has shut the run down."""


class TrajectoryQueue:
    """FIFO of :class:`TrajectoryItem` with consume-time admission."""

    def __init__(
        self,
        maxsize: int = 0,
        admission: Optional[AdmissionPolicy] = None,
        tracer: Tracer = NULL_TRACER,
        registry: Any = None,
        injector: FaultInjector = NULL_INJECTOR,
        fallback_admission: Optional[AdmissionPolicy] = None,
        fallback_max_lag: int = 4,
    ) -> None:
        """``tracer`` gets put/pop/drop instants (with the TV verdict
        and lag at decision time) plus a queue-depth counter track;
        ``registry`` (an ``obs.MetricsRegistry``) gets this queue's
        stats as the ``"queue"`` producer so one ``snapshot()`` covers
        serve and runtime alike."""
        self.maxsize = maxsize
        self.admission = admission or PassThrough()
        self.tracer = tracer
        if registry is None:
            from repro.obs.registry import MetricsRegistry

            registry = MetricsRegistry()
        else:
            registry.register_producer(
                "queue", lambda: self.stats().as_dict())
        self.registry = registry
        self._dq: collections.deque = collections.deque()
        self._cond = threading.Condition()
        self._closed = False
        # counters (guarded by _cond's lock)
        self._puts = 0
        self._admitted = 0
        self._dropped = 0
        self._downweighted = 0
        # Labelled registry counters, keyed (outcome, reason).  The
        # per-reason dicts in stats() are derived views of these — one
        # source of truth, visible in registry.snapshot() as
        # queue_admission_total{controller=...,outcome=...,reason=...}.
        self._decision_counters: Dict[tuple, Any] = {}
        self._lag_histogram = LagHistogram()
        # Resilience: fault hooks + the max_lag fallback admission used
        # when the configured controller raises mid-run (graceful
        # degradation — a buggy controller must not kill the trainer).
        self.injector = injector
        self._gets = 0
        self._fallback_max_lag = int(fallback_max_lag)
        self._fallback = fallback_admission
        self._fallback_active = False

    def _fallback_admission(self) -> AdmissionPolicy:
        if self._fallback is None:
            from repro.runtime.admission import MaxLagEviction

            self._fallback = MaxLagEviction(max_lag=self._fallback_max_lag)
        return self._fallback

    def _count_decision(self, outcome: str, reason: str) -> None:
        """Bump queue_admission_total{controller,outcome,reason} (must be
        called with ``_cond`` held)."""
        key = (outcome, reason)
        counter = self._decision_counters.get(key)
        if counter is None:
            counter = self.registry.counter(
                "queue_admission_total",
                controller=self.admission.name,
                outcome=outcome, reason=reason)
            self._decision_counters[key] = counter
        counter.inc()

    def _by_reason(self, outcome: str) -> Dict[str, int]:
        return {
            reason: int(c.value)
            for (o, reason), c in self._decision_counters.items()
            if o == outcome
        }

    def admission_counters(self) -> Dict[str, int]:
        """The labelled-counter view, rendered Prometheus-style — the
        same strings ``registry.snapshot()['counters']`` shows, readable
        without re-entering the registry's snapshot producers."""
        name = self.admission.name
        with self._cond:
            return {
                f"queue_admission_total{{controller={name},"
                f"outcome={o},reason={r}}}": int(c.value)
                for (o, r), c in self._decision_counters.items()
            }

    # -- producer side -------------------------------------------------------

    def put(
        self,
        payload: Any,
        *,
        behavior_version: int,
        learner_version: int,
        behavior_version_newest: Optional[int] = None,
        **meta: Any,
    ) -> TrajectoryItem:
        """Enqueue; blocks when bounded and full (producer backpressure)."""
        item = TrajectoryItem(
            payload=payload,
            behavior_version=int(behavior_version),
            enqueue_learner_version=int(learner_version),
            behavior_version_newest=(
                None if behavior_version_newest is None
                else int(behavior_version_newest)),
            meta=dict(meta),
        )
        if self.injector.active:
            with self._cond:
                call = self._puts + 1
            self.injector.stall("queue_put", at_call=call)
        with self._cond:
            while (
                self.maxsize > 0
                and len(self._dq) >= self.maxsize
                and not self._closed
            ):
                self._cond.wait()
            if self._closed:
                raise QueueClosed("put() on a closed TrajectoryQueue")
            self._dq.append(item)
            self._puts += 1
            depth = len(self._dq)
            self._cond.notify_all()
        tr = self.tracer
        if tr.enabled:
            tr.instant("queue_put", pid="runtime", tid="queue",
                       behavior_version=item.behavior_version,
                       lag=item.lag)
            tr.counter("queue_depth", pid="runtime", depth=float(depth))
        return item

    def close(self) -> None:
        """Wake all waiters; further puts raise, gets drain then None."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    # -- consumer side -------------------------------------------------------

    def get(
        self,
        *,
        learner_version: int,
        timeout: Optional[float] = None,
    ) -> Optional[TrajectoryItem]:
        """Next admitted item, stamped with the learner's version.

        Pops until the admission policy accepts an item; rejected items are
        counted as drops.  Returns None when the queue is closed and
        drained, or when `timeout` elapses with nothing available.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        if self.injector.active:
            with self._cond:
                self._gets += 1
                call = self._gets
            self.injector.stall("queue_get", at_call=call)
        while True:
            with self._cond:
                while not self._dq and not self._closed:
                    if deadline is None:
                        self._cond.wait()
                    else:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0 or not self._cond.wait(remaining):
                            if not self._dq:
                                return None
                if not self._dq:
                    return None  # closed and drained
                item = self._dq.popleft()
                self._cond.notify_all()
            # Admission runs outside the lock: tv_fn may dispatch a jitted
            # forward pass and must not stall the producer.
            item.learner_version_at_consume = int(learner_version)
            try:
                decision = self.admission.admit(item)
            except Exception as exc:
                # Graceful degradation: a raising controller downgrades
                # the run to plain max_lag admission instead of killing
                # the learner.  Counted + traced so the fallback is a
                # measured event, not a silent behavior change.
                self.registry.counter(
                    "admission_fallback_total",
                    controller=self.admission.name).inc()
                if self.tracer.enabled:
                    self.tracer.instant(
                        "admission_fallback", pid="runtime", tid="queue",
                        controller=self.admission.name, error=repr(exc))
                if not self._fallback_active:
                    self._fallback_active = True
                    import warnings

                    warnings.warn(
                        f"admission controller {self.admission.name!r} "
                        f"raised ({exc!r}); falling back to "
                        f"max_lag:{self._fallback_max_lag}",
                        RuntimeWarning, stacklevel=2)
                decision = self._fallback_admission().admit(item)
            # A decision must say *why* — reasons label the registry
            # counters, so an empty one would silently merge outcomes.
            reason = decision.reason
            if not reason:
                raise ValueError(
                    f"{type(self.admission).__name__} "
                    f"({self.admission.name!r}) returned an "
                    "AdmissionDecision with an empty reason; reasons "
                    "are mandatory (use e.g. 'admit')")
            tr = self.tracer
            with self._cond:
                if not decision.admit:
                    self._dropped += 1
                    self._count_decision("drop", reason)
                    depth = len(self._dq)
                    if tr.enabled:
                        tr.instant(
                            "queue_drop", pid="runtime", tid="queue",
                            reason=reason, lag=item.lag,
                            tv=item.tv if item.tv is not None
                            else decision.tv)
                        tr.counter("queue_depth", pid="runtime",
                                   depth=float(depth))
                    continue
                item.weight = float(decision.weight)
                item.tv = decision.tv
                if decision.weight != 1.0:
                    self._downweighted += 1
                    self._count_decision("downweight", reason)
                else:
                    self._count_decision("admit", reason)
                self._admitted += 1
                self._lag_histogram.record(item.lag)
                depth = len(self._dq)
            if item.meta.get("restart"):
                # First admitted batch from a restarted producer: the
                # recovery lag spike, measured at the gate.
                self.registry.counter("restart_admitted_total").inc()
                if tr.enabled:
                    tr.instant("restart_admitted", pid="runtime",
                               tid="queue", lag=item.lag,
                               lag_oldest=item.lag_oldest,
                               lag_newest=item.lag_newest)
            if tr.enabled:
                tr.instant("queue_pop", pid="runtime", tid="queue",
                           lag=item.lag, weight=item.weight,
                           tv=item.tv)
                tr.counter("queue_depth", pid="runtime",
                           depth=float(depth))
            return item

    # -- introspection -------------------------------------------------------

    def qsize(self) -> int:
        with self._cond:
            return len(self._dq)

    def stats(self) -> RuntimeQueueStats:
        with self._cond:
            consumed = self._admitted + self._dropped
            return RuntimeQueueStats(
                depth=len(self._dq),
                puts=self._puts,
                admitted=self._admitted,
                dropped=self._dropped,
                downweighted=self._downweighted,
                admission_drop_rate=(
                    self._dropped / consumed if consumed else 0.0
                ),
                drops_by_reason=self._by_reason("drop"),
                lag_histogram=self._lag_histogram.snapshot(),
                controller=self.admission.name,
                downweights_by_reason=self._by_reason("downweight"),
            )
