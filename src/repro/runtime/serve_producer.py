"""ServeRolloutProducer: the ServeEngine as the RLVR trainer's producer.

Closes the loop the paper opens: instead of the synthetic
``ForwardLagGenerator``, rollout generation goes through the real serve
path — continuous batching over a paged KV cache, optional prefix
sharing and speculative decode, and in-flight weight swaps — and the
engine's exact per-token ``{version, log_beta}`` provenance flows
straight into the trajectory queue, where the lag controllers consume
it.

One produced item is one :class:`~repro.rollout.async_engine.RLVRMinibatch`
(the same payload the legacy generator emits, so the whole trainer/
controller stack is producer-agnostic): ``prompts_per_minibatch``
problems are sampled, each submitted ``completions_per_prompt`` times
(contiguous GRPO groups), the engine is stepped until every request
retires, and the retired trajectories are re-assembled into the
fixed-shape ``[B, P+N]`` batch the jitted update consumes.

**Padding discipline (correctness-critical):** the engine is handed the
*full left-padded* prompt row, exactly as ``sampler.generate`` sees it —
pad tokens are attended in the causal mask, so stripping them (as the
serving launcher does) would make the engine's ``log_beta`` disagree
with ``score_tokens``'s ``log_pi`` on identical weights.  With the
padded prompt the realignment ratio is exactly 1 for fresh data, which
is what the TV gate's calibration assumes.

Two modes:

* **phase-locked** (default): ``fill()`` produces one minibatch
  synchronously — deterministic at fixed seed, which the direction
  tests and the lag-sweep bench rely on.  ``version_offset=k`` forces
  the engine to generate from the learner's ``k``-back snapshot
  (resident-ring clamped), giving an exact, scripted lag with real
  engine provenance.
* **threaded**: a producer thread keeps generating from the freshest
  swapped-in weights while the learner consumes concurrently — lag now
  arises from real timing, as in production.
"""
from __future__ import annotations

import threading
from typing import Any, Optional, TYPE_CHECKING

import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import PAD
from repro.resilience.faults import FaultInjector, NULL_INJECTOR
from repro.resilience.supervision import (
    BackoffPolicy,
    RestartContext,
    supervise,
)
from repro.runtime.policy_store import PolicyStore
from repro.runtime.queue import QueueClosed, TrajectoryQueue
from repro.runtime.regimes import LagRegime

if TYPE_CHECKING:  # pragma: no cover
    from repro.rollout.async_engine import RLVRMinibatch


class ServeRolloutProducer(LagRegime):
    """Drive RLVR generation through a continuous-batching ServeEngine."""

    name = "serve"

    def __init__(
        self,
        store: PolicyStore,
        queue: TrajectoryQueue,
        engine: Any,              # serve.ServeEngine bound to `store`
        dataset: Any,             # data.mathgen.MathTaskDataset
        *,
        prompts_per_minibatch: int,
        completions_per_prompt: int,
        max_new_tokens: int,
        version_offset: Optional[int] = None,
        threaded: bool = False,
        max_items: Optional[int] = None,
        injector: FaultInjector = NULL_INJECTOR,
        supervisor: Optional[BackoffPolicy] = None,
    ) -> None:
        if engine.store is not store:
            raise ValueError(
                "engine must share the producer's PolicyStore (weight "
                "swaps are how learner publishes reach generation)")
        super().__init__(store, queue)
        self.engine = engine
        self.dataset = dataset
        self.prompts_per_minibatch = prompts_per_minibatch
        self.group_size = completions_per_prompt
        self.max_new_tokens = max_new_tokens
        self.version_offset = version_offset
        self.phase_locked = not threaded
        self.max_items = max_items
        self.injector = injector
        self.supervisor = supervisor
        self.produced = 0
        self.restarts = 0
        self.error: Optional[BaseException] = None
        self._stop_event = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._version_at_crash: Optional[int] = None
        self._restart_floor: Optional[int] = None
        self._last_timeouts = 0
        if version_offset is not None:
            if version_offset < 0:
                raise ValueError(
                    f"version_offset must be >= 0, got {version_offset}")
            # Forced lag owns the engine's weights: the producer pins
            # the k-back snapshot before every minibatch, so the
            # engine's own store polling must never override it
            # (swap_interval=0 disables _maybe_swap entirely — a large
            # interval would still fire at stats.steps == 0).
            self.engine.swap_interval = 0

    # -- forced lag ----------------------------------------------------------

    def _apply_forced_lag(self) -> None:
        if self.version_offset is None:
            return
        # Nearest resident version at (or older than) latest - offset;
        # falls back to the oldest retained snapshot when the ring is
        # shallower than the requested lag.
        target = self.store.resolve_lagged(-self.version_offset)
        if target != self.engine.version:
            self.engine.params = self.store.get(target)
            self.engine.version = target

    # -- one minibatch through the engine ------------------------------------

    def _produce_minibatch(self) -> "RLVRMinibatch":
        # Imported here: rollout.async_engine imports runtime modules at
        # module load (a package-level cycle otherwise).
        from repro.data.mathgen import verify
        from repro.rollout.async_engine import RLVRMinibatch
        from repro.rollout.sampler import GenerationResult

        self.injector.crash_if(
            "producer", at_step=self.produced, producer=self.name)
        self._apply_forced_lag()
        self._version_at_crash = int(self.engine.version)
        tok = self.dataset.tok
        prompt_len = self.dataset.prompt_len
        n_new = self.max_new_tokens
        toks_np, _, answers = self.dataset.sample_batch(
            self.prompts_per_minibatch)
        toks_np = np.repeat(toks_np, self.group_size, axis=0)
        answers = [a for a in answers for _ in range(self.group_size)]
        batch = toks_np.shape[0]

        with self.tracer.span("produce", pid="runtime", tid="producer",
                              version=self.engine.version):
            pending = {}
            for i in range(batch):
                req = self.engine.submit(toks_np[i], n_new)
                pending[req.request_id] = i
            done: dict = {}
            self._last_timeouts = 0
            while len(done) < batch:
                if not self.engine.has_work:
                    raise RuntimeError(
                        "serve producer: engine drained with "
                        f"{batch - len(done)} requests outstanding")
                for traj in self.engine.step():
                    idx = pending.pop(traj.request_id, None)
                    if idx is not None:
                        done[idx] = traj
                        if traj.finish_reason == "timeout":
                            # Deadline retirement: the row stays in the
                            # fixed-shape batch with whatever tokens it
                            # emitted (possibly none — fully masked).
                            self._last_timeouts += 1

        tokens = np.full((batch, prompt_len + n_new), PAD, np.int32)
        tokens[:, :prompt_len] = toks_np
        log_beta = np.zeros((batch, n_new), np.float32)
        mask = np.zeros((batch, n_new), np.float32)
        versions = np.zeros((batch, n_new), np.int64)
        for i, traj in done.items():
            n = traj.num_tokens
            tokens[i, prompt_len:prompt_len + n] = traj.tokens
            log_beta[i, :n] = traj.log_beta
            mask[i, :n] = traj.mask
            versions[i, :n] = traj.versions
            # Pad the version record with the row's last real version so
            # segmenting gates see no phantom boundary at the tail.
            versions[i, n:] = (traj.versions[-1] if n
                               else self.engine.version)

        completion = tokens[:, prompt_len:]
        rewards = jnp.asarray(
            [verify(tok.decode(row), ans)
             for row, ans in zip(completion, answers)],
            jnp.float32)
        gen = GenerationResult(
            tokens=jnp.asarray(tokens),
            completion=jnp.asarray(completion),
            log_beta=jnp.asarray(log_beta),
            mask=jnp.asarray(mask),
            values=None,
        )
        return RLVRMinibatch(gen=gen, rewards=rewards, answers=answers,
                             versions=versions)

    def _put(self, mb: "RLVRMinibatch", **meta: Any) -> None:
        versions = np.asarray(mb.versions)
        oldest = int(versions.min())
        if meta.get("restart") and self._restart_floor is not None:
            # Conservative provenance for a restarted producer's first
            # batch: span the outage so admission measures the true
            # worst-case staleness instead of being bypassed.
            oldest = min(oldest, self._restart_floor)
            self._restart_floor = None
        self.queue.put(
            mb,
            behavior_version=oldest,
            learner_version=self.store.version,
            behavior_version_newest=int(versions.max()),
            producer="serve",
            timeouts=self._last_timeouts,
            **meta,
        )

    # -- phase-locked mode ---------------------------------------------------

    def fill(self) -> None:
        self._put(self._produce_minibatch())

    # -- threaded mode -------------------------------------------------------

    def start(self) -> None:
        if self.phase_locked:
            return
        self._thread = threading.Thread(
            target=self._loop, name="serve-producer", daemon=True)
        self._thread.start()

    def _run(self, ctx: RestartContext) -> None:
        restart_pending = ctx.attempt > 0
        if restart_pending:
            # The version generation was pinned to when the crash hit —
            # the floor for the first recovered batch's span.
            self._restart_floor = self._version_at_crash
        if restart_pending and self.version_offset is None:
            # Re-pin the current store version for the restarted
            # incarnation (forced-lag mode re-pins per minibatch).
            params, version = self.store.latest()
            self.engine.params = params
            self.engine.version = version
        while not self._stop_event.is_set() and (
            self.max_items is None or self.produced < self.max_items
        ):
            mb = self._produce_minibatch()
            meta = ({"restart": True, "restart_attempt": ctx.attempt}
                    if restart_pending else {})
            try:
                self._put(mb, **meta)
            except QueueClosed:
                break
            restart_pending = False
            self.produced += 1

    def _loop(self) -> None:
        try:
            if self.supervisor is None:
                self._run(RestartContext())
            else:
                self.restarts = supervise(
                    self._run,
                    policy=self.supervisor,
                    name=self.name,
                    should_stop=self._stop_event.is_set,
                    clean_exits=(QueueClosed,),
                    registry=self.queue.registry,
                    tracer=self.tracer,
                )
        except BaseException as e:   # surface crashes, don't hang
            self.error = e
        finally:
            self.queue.close()

    def next_item(self, learner_version, *, timeout=None, max_refills=50):
        item = super().next_item(
            learner_version, timeout=timeout, max_refills=max_refills)
        if item is None and self.error is not None:
            raise RuntimeError("serve producer crashed") from self.error
        return item

    def stop(self, join_timeout: float = 30.0) -> None:
        if self.phase_locked:
            return
        self._stop_event.set()
        self.queue.close()
        if self._thread is not None:
            self._thread.join(timeout=join_timeout)
