"""Controller specs, the controller registry, and the lag-controller zoo.

One grammar everywhere (``launch.train``, ``launch.serve``,
``bench_runtime``)::

    --controller "name"
    --controller "name:key=val,key=val"

e.g. ``tv_gate:delta=0.2,mode=downweight``.  Values parse as int, float,
bool (``true``/``false``) or string; unknown controller names and
unknown option keys are hard errors (the old string-keyed factory
silently ignored stray kwargs).

Registered controllers:

=================  =====================================================
``pass_through``   admit everything (phase-locked baseline)
``max_lag``        span-aware version-age eviction
``tv_gate``        the paper's Eq. 8 trust-region gate (drop/downweight)
``tv_gate_tokenwise``  Eq. 8 per version segment of a served trajectory
``gac``            gradient cosine-alignment clip vs a fresh-anchor EMA
``stable_async``   variance-controlled truncated importance correction
``asympo``         behavior-free asymmetric advantage scaling
=================  =====================================================

The last three implement the PAPERS.md related work and exercise the
:class:`~repro.runtime.admission.LagController` hooks beyond admission:
``gac`` needs raw gradients, ``stable_async`` needs the learner's
current log-probs, ``asympo`` needs neither (usable when the producer
cannot report log_beta at all).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Tuple, Union

import numpy as np

from repro.runtime.admission import (
    LagController,
    MaxLagEviction,
    PassThrough,
    TokenwiseTVGate,
    TVGatedAdmission,
)

__all__ = [
    "AsymPOController",
    "ControllerContext",
    "ControllerSpec",
    "GradientAlignmentController",
    "StableAsyncController",
    "available_controllers",
    "make_controller",
    "parse_controller_spec",
    "register_controller",
    "spec_from_legacy",
]


# --------------------------------------------------------------------------
# Spec grammar
# --------------------------------------------------------------------------


def _parse_value(text: str) -> Any:
    low = text.strip().lower()
    if low in ("true", "false"):
        return low == "true"
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            continue
    return text


@dataclasses.dataclass(frozen=True)
class ControllerSpec:
    """A parsed ``name:key=val,...`` controller spec (hashable)."""

    name: str
    opts: Tuple[Tuple[str, Any], ...] = ()

    @property
    def options(self) -> Dict[str, Any]:
        return dict(self.opts)

    def canonical(self) -> str:
        if not self.opts:
            return self.name
        body = ",".join(f"{k}={v}" for k, v in self.opts)
        return f"{self.name}:{body}"

    def __str__(self) -> str:  # pragma: no cover - convenience
        return self.canonical()


def parse_controller_spec(text: Union[str, ControllerSpec]) -> ControllerSpec:
    """``"tv_gate:delta=0.2,mode=downweight"`` -> :class:`ControllerSpec`."""
    if isinstance(text, ControllerSpec):
        return text
    text = text.strip()
    if not text:
        raise ValueError("empty controller spec")
    name, _, body = text.partition(":")
    name = name.strip()
    if name not in CONTROLLER_REGISTRY:
        raise ValueError(
            f"unknown controller {name!r}; available: "
            f"{', '.join(sorted(CONTROLLER_REGISTRY))}")
    opts = []
    if body.strip():
        for chunk in body.split(","):
            key, eq, val = chunk.partition("=")
            key = key.strip()
            if not key or not eq:
                raise ValueError(
                    f"bad controller option {chunk!r} in {text!r} "
                    "(expected key=value)")
            opts.append((key, _parse_value(val)))
    return ControllerSpec(name=name, opts=tuple(opts))


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ControllerContext:
    """Host-side capabilities a builder may need (closed over the
    policy store / model apply by the caller)."""

    tv_fn: Optional[Callable[[Any], float]] = None
    token_tv_fn: Optional[Callable[[Any], Any]] = None


@dataclasses.dataclass(frozen=True)
class ControllerInfo:
    name: str
    builder: Callable[[Dict[str, Any], ControllerContext], LagController]
    description: str
    paper: str = ""


CONTROLLER_REGISTRY: Dict[str, ControllerInfo] = {}


def register_controller(
    name: str, *, description: str, paper: str = ""
) -> Callable:
    def deco(builder: Callable) -> Callable:
        CONTROLLER_REGISTRY[name] = ControllerInfo(
            name=name, builder=builder, description=description, paper=paper)
        return builder
    return deco


def available_controllers() -> Dict[str, ControllerInfo]:
    return dict(CONTROLLER_REGISTRY)


def _take(options: Dict[str, Any], name: str, **defaults: Any) -> Dict[str, Any]:
    """Merge spec options over defaults; unknown keys are hard errors."""
    unknown = set(options) - set(defaults)
    if unknown:
        raise ValueError(
            f"unknown option(s) {sorted(unknown)} for controller "
            f"{name!r}; accepted: {sorted(defaults)}")
    out = dict(defaults)
    out.update(options)
    return out


def make_controller(
    spec: Union[str, ControllerSpec],
    *,
    tv_fn: Optional[Callable[[Any], float]] = None,
    token_tv_fn: Optional[Callable[[Any], Any]] = None,
) -> LagController:
    """Build a :class:`LagController` from a spec (string or parsed)."""
    spec = parse_controller_spec(spec)
    ctx = ControllerContext(tv_fn=tv_fn, token_tv_fn=token_tv_fn)
    return CONTROLLER_REGISTRY[spec.name].builder(spec.options, ctx)


_LEGACY_NAMES = ("pass_through", "max_lag", "tv_gate", "tv_gate_tokenwise")


def spec_from_legacy(
    name: str,
    *,
    max_lag: int = 4,
    delta: float = 0.2,
    mode: str = "drop",
    warn: bool = False,
) -> ControllerSpec:
    """Map the legacy ``--admission``/``--max-lag``/``--admission-mode``
    flag triple onto a :class:`ControllerSpec` (the deprecation shim)."""
    if name not in _LEGACY_NAMES:
        raise ValueError(f"unknown admission policy {name!r}")
    if name == "pass_through":
        spec = ControllerSpec("pass_through")
    elif name == "max_lag":
        spec = ControllerSpec("max_lag", (("max_lag", int(max_lag)),))
    else:
        spec = ControllerSpec(
            name, (("delta", float(delta)), ("mode", str(mode))))
    if warn:
        warnings.warn(
            f"--admission {name!r} is deprecated; use "
            f"--controller {spec.canonical()!r}",
            DeprecationWarning, stacklevel=2)
    return spec


# --------------------------------------------------------------------------
# Builders for the existing admission-only controllers
# --------------------------------------------------------------------------


@register_controller(
    "pass_through",
    description="admit everything at full weight (phase-locked baseline)")
def _build_pass_through(options, ctx):
    _take(options, "pass_through")
    return PassThrough()


@register_controller(
    "max_lag",
    description="span-aware version-age eviction (drop > max_lag, "
                "fractional weight on straddling mixtures)")
def _build_max_lag(options, ctx):
    o = _take(options, "max_lag", max_lag=4)
    return MaxLagEviction(int(o["max_lag"]))


@register_controller(
    "tv_gate",
    description="Eq. 8 trust-region gate on the sampled TV estimate",
    paper="Align and Filter (this repo's source paper)")
def _build_tv_gate(options, ctx):
    o = _take(options, "tv_gate", delta=0.2, mode="drop")
    if ctx.tv_fn is None:
        raise ValueError("tv_gate admission requires a tv_fn")
    return TVGatedAdmission(float(o["delta"]), ctx.tv_fn, mode=o["mode"])


@register_controller(
    "tv_gate_tokenwise",
    description="Eq. 8 applied per version segment of a served trajectory",
    paper="Align and Filter (this repo's source paper)")
def _build_tv_gate_tokenwise(options, ctx):
    o = _take(options, "tv_gate_tokenwise", delta=0.2, mode="downweight")
    fn = ctx.token_tv_fn or ctx.tv_fn
    if fn is None:
        raise ValueError(
            "tv_gate_tokenwise admission requires a tv_fn returning "
            "(tv_tokens, versions)")
    return TokenwiseTVGate(float(o["delta"]), fn, mode=o["mode"])


# --------------------------------------------------------------------------
# GAC: gradient cosine-alignment clip
# --------------------------------------------------------------------------


def _tree_dot_norms(a, b):
    """(a·b, ||a||, ||b||) over two pytrees, computed lazily in jax so
    the reduction fuses; imported here to keep module import light."""
    import jax
    import jax.numpy as jnp

    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    dot = sum(jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
              for x, y in zip(la, lb))
    na = jnp.sqrt(sum(jnp.vdot(x.astype(jnp.float32),
                               x.astype(jnp.float32)) for x in la))
    nb = jnp.sqrt(sum(jnp.vdot(y.astype(jnp.float32),
                               y.astype(jnp.float32)) for y in lb))
    return dot, na, nb


class GradientAlignmentController(LagController):
    """GAC: clip stale-minibatch gradients by cosine alignment with a
    fresh-anchor gradient EMA.

    Fresh minibatches (``lag <= fresh_lag``) update the anchor and pass
    through untouched.  A stale minibatch's gradient is compared to the
    anchor: cosine >= ``cos_min`` passes at full scale (and refreshes
    the anchor — an aligned stale gradient is information about the
    current objective too); below that the gradient is linearly scaled
    down to ``min_scale`` at cosine 0 and clipped to ``min_scale`` for
    negative cosines, so a stale update can reduce to (near) a no-op but
    never actively fights the fresh descent direction.
    """

    name = "gac"
    needs_gradients = True

    def __init__(
        self,
        cos_min: float = 0.25,
        fresh_lag: int = 0,
        ema: float = 0.9,
        min_scale: float = 0.0,
    ) -> None:
        if not 0.0 <= ema < 1.0:
            raise ValueError(f"ema must be in [0, 1), got {ema}")
        self.cos_min = float(cos_min)
        self.fresh_lag = int(fresh_lag)
        self.ema = float(ema)
        self.min_scale = float(min_scale)
        self._anchor = None
        self._cos_fn = None

    def _cosine(self, grads) -> float:
        import jax

        if self._cos_fn is None:
            self._cos_fn = jax.jit(_tree_dot_norms)
        dot, na, nb = self._cos_fn(grads, self._anchor)
        denom = float(na) * float(nb)
        return float(dot) / denom if denom > 0 else 0.0

    def _update_anchor(self, grads) -> None:
        import jax

        if self._anchor is None:
            self._anchor = jax.tree.map(lambda g: g, grads)
        else:
            e = self.ema
            self._anchor = jax.tree.map(
                lambda a, g: e * a + (1.0 - e) * g, self._anchor, grads)

    def transform_gradients(self, item, grads):
        import jax

        if self._anchor is None or item.lag <= self.fresh_lag:
            self._update_anchor(grads)
            return grads, {"gac_cos": 1.0, "gac_scale": 1.0}
        cos = self._cosine(grads)
        if cos >= self.cos_min:
            scale = 1.0
            self._update_anchor(grads)
        elif cos <= 0.0 or self.cos_min <= 0.0:
            scale = self.min_scale
        else:
            scale = self.min_scale + (1.0 - self.min_scale) * (
                cos / self.cos_min)
        if scale != 1.0:
            grads = jax.tree.map(lambda g: g * scale, grads)
        return grads, {"gac_cos": float(cos), "gac_scale": float(scale)}


@register_controller(
    "gac",
    description="cosine-alignment clip of stale gradients vs a "
                "fresh-anchor gradient EMA",
    paper="Gradient-Alignment Control for asynchronous RL (GAC)")
def _build_gac(options, ctx):
    o = _take(options, "gac", cos_min=0.25, fresh_lag=0, ema=0.9,
              min_scale=0.0)
    return GradientAlignmentController(
        cos_min=float(o["cos_min"]), fresh_lag=int(o["fresh_lag"]),
        ema=float(o["ema"]), min_scale=float(o["min_scale"]))


# --------------------------------------------------------------------------
# Stable Asynchrony: variance-controlled truncated importance correction
# --------------------------------------------------------------------------


class StableAsyncController(LagController):
    """Per-token truncated importance weights ``min(pi/beta, c)`` with
    the truncation level ``c`` chosen per minibatch so the weight
    variance stays under ``var_max``.

    The variance of the truncated weights is monotone in ``c``, so the
    largest admissible ``c`` in ``[c_min, c_max]`` — least truncation,
    least bias — is found by bisection.  On-policy data has ratio ~1
    and passes essentially unweighted; the controller only bites when
    lag has moved the current policy away from the recorded ``log_beta``
    (which is why it declares ``needs_log_pi``).
    """

    name = "stable_async"
    needs_log_pi = True

    def __init__(
        self,
        c_max: float = 2.0,
        c_min: float = 1.0,
        var_max: float = 0.5,
        iters: int = 25,
    ) -> None:
        if c_min <= 0 or c_max < c_min:
            raise ValueError(
                f"need 0 < c_min <= c_max, got {c_min}, {c_max}")
        self.c_max = float(c_max)
        self.c_min = float(c_min)
        self.var_max = float(var_max)
        self.iters = int(iters)

    def loss_weights(self, item, *, advantages, log_beta, mask,
                     log_pi=None):
        if log_pi is None:
            raise ValueError(
                "stable_async requires current log-probs (needs_log_pi)")
        rho = np.exp(np.clip(
            np.asarray(log_pi, np.float64) - np.asarray(log_beta,
                                                        np.float64),
            -20.0, 20.0))
        valid = np.asarray(mask) > 0
        if not valid.any():
            return None

        def var_at(c: float) -> float:
            return float(np.minimum(rho, c)[valid].var())

        if var_at(self.c_max) <= self.var_max:
            c = self.c_max
        elif var_at(self.c_min) > self.var_max:
            c = self.c_min
        else:
            lo, hi = self.c_min, self.c_max
            for _ in range(self.iters):
                mid = 0.5 * (lo + hi)
                if var_at(mid) <= self.var_max:
                    lo = mid
                else:
                    hi = mid
            c = lo
        w = np.minimum(rho, c)
        item.meta["stable_async"] = {
            "c": float(c), "var": var_at(c),
            "mean_weight": float(w[valid].mean()),
        }
        return np.where(valid, w, 1.0).astype(np.float32)


@register_controller(
    "stable_async",
    description="variance-controlled truncated importance correction "
                "from log_beta vs current log-probs",
    paper="Stable Asynchronous RL (variance-controlled off-policy "
          "correction)")
def _build_stable_async(options, ctx):
    o = _take(options, "stable_async", c_max=2.0, c_min=1.0, var_max=0.5)
    return StableAsyncController(
        c_max=float(o["c_max"]), c_min=float(o["c_min"]),
        var_max=float(o["var_max"]))


# --------------------------------------------------------------------------
# ASymPO: behavior-free asymmetric advantage scaling
# --------------------------------------------------------------------------


class AsymPOController(LagController):
    """Asymmetric advantage scaling that needs *no* behavior log-probs.

    Stale positive advantages are the dangerous direction — they keep
    reinforcing actions the current policy may no longer prefer — so
    the positive side decays geometrically with lag
    (``pos_scale * pos_decay**lag``) while the negative (conservative,
    probability-lowering) side keeps a fixed ``neg_scale``.  Because
    only the item's lag and the advantage sign are consulted, this
    controller works when the producer cannot report ``log_beta`` at
    all (e.g. a fleet of inference hosts with approximate sampling).
    """

    name = "asympo"

    def __init__(
        self,
        pos_scale: float = 1.0,
        neg_scale: float = 1.0,
        pos_decay: float = 0.9,
    ) -> None:
        if not 0.0 < pos_decay <= 1.0:
            raise ValueError(f"pos_decay must be in (0, 1], got {pos_decay}")
        self.pos_scale = float(pos_scale)
        self.neg_scale = float(neg_scale)
        self.pos_decay = float(pos_decay)

    def loss_weights(self, item, *, advantages, log_beta, mask,
                     log_pi=None):
        lag = max(int(item.lag), 0)
        w_pos = self.pos_scale * (self.pos_decay ** lag)
        adv = np.asarray(advantages, np.float64).reshape(-1)
        w_seq = np.where(adv > 0.0, w_pos, self.neg_scale)
        item.meta["asympo"] = {
            "w_pos": float(w_pos), "w_neg": self.neg_scale, "lag": lag}
        n_tok = np.asarray(mask).shape[1]
        return np.repeat(
            w_seq[:, None].astype(np.float32), n_tok, axis=1)


@register_controller(
    "asympo",
    description="behavior-free asymmetric advantage scaling (positive "
                "side decays with lag)",
    paper="ASymPO: asymmetric-scale policy optimization without "
          "behavior log-probs")
def _build_asympo(options, ctx):
    o = _take(options, "asympo", pos_scale=1.0, neg_scale=1.0,
              pos_decay=0.9)
    return AsymPOController(
        pos_scale=float(o["pos_scale"]), neg_scale=float(o["neg_scale"]),
        pos_decay=float(o["pos_decay"]))
