"""Lag regimes: three drivers of the same PolicyStore/TrajectoryQueue API.

The paper's two phase-locked simulators and a genuinely concurrent mode
become interchangeable *drivers* of one runtime:

* ``backward_mixture`` — §5.1 / Fig. 1 left.  Each ``fill()`` samples one
  stale snapshot per actor from the store's ring and produces a single
  mixture rollout (the episodic mixture behavior policy of Eq. 1).
* ``forward_n`` — §5.2.  Each ``fill()`` freezes ``store.latest()`` and
  produces N items from it; the learner then takes N updates, so item k
  is consumed with forward lag k (generate-N/train-N, Noukhovitch-style).
* ``threaded`` — a real producer thread continuously generates from the
  newest available snapshot while the learner consumes concurrently; lag
  now arises from actual timing rather than a scripted schedule.  The
  bounded queue provides backpressure.

Producers are plain callables so the same regimes drive both classic-RL
env rollouts and RLVR completion generation.  ``MixtureRolloutProducer``
reproduces ``SimulatedAsyncActors``'s jit structure and PRNG discipline
bit-for-bit; ``FrozenRolloutProducer`` is its single-policy counterpart
for the forward/threaded regimes.
"""
from __future__ import annotations

import threading
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy_lag import PolicyBuffer, buffer_sample
from repro.envs.base import Env
from repro.resilience.faults import FaultInjector, NULL_INJECTOR
from repro.resilience.supervision import (
    BackoffPolicy,
    RestartContext,
    supervise,
)
from repro.rollout.env_rollout import collect_rollout, init_env_states
from repro.runtime.policy_store import PolicyStore
from repro.runtime.queue import QueueClosed, TrajectoryQueue


# ---------------------------------------------------------------------------
# Producers (classic RL).  RLVR producers live on ForwardLagGenerator.
# ---------------------------------------------------------------------------


class _RolloutProducer:
    """Shared scaffolding for env-rollout producers: one PRNG chain
    (whose first split seeds the env states — bit-exactness-critical
    ordering) and a jitted collect threading persistent env states.

    Subclasses define ``_make_collect`` mapping
    (policy_source, env_states, key) -> (env_states, *outputs).
    """

    def __init__(
        self,
        env: Env,
        policy_apply: Callable,
        *,
        n_actors: int,
        rollout_steps: int,
        seed: int = 0,
    ) -> None:
        self.env = env
        self.n_actors = n_actors
        self.rollout_steps = rollout_steps
        self._key = jax.random.PRNGKey(seed)
        self._env_states = init_env_states(env, self._next_key(), n_actors)
        self._collect = jax.jit(self._make_collect(env, policy_apply))

    def _make_collect(self, env: Env, policy_apply: Callable) -> Callable:
        raise NotImplementedError

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def __call__(self, policy_source: Any):
        self._env_states, *outputs = self._collect(
            policy_source, self._env_states, self._next_key()
        )
        return outputs[0] if len(outputs) == 1 else tuple(outputs)


class MixtureRolloutProducer(_RolloutProducer):
    """Vectorized env rollout with per-actor policies from a snapshot ring.

    ``producer(buffer) -> (RolloutBatch, slots)`` — sampling happens
    *inside* the jitted collect (identical graph to the legacy
    ``SimulatedAsyncActors``), so refactored runs are bit-identical.
    """

    def _make_collect(self, env: Env, policy_apply: Callable) -> Callable:
        n_actors, rollout_steps = self.n_actors, self.rollout_steps

        def _collect(buffer: PolicyBuffer, env_states, key):
            k_sample, k_roll = jax.random.split(key)
            actor_params, slots = buffer_sample(buffer, k_sample, n_actors)
            env_states, batch = collect_rollout(
                env, policy_apply, actor_params, env_states, k_roll,
                rollout_steps,
            )
            return env_states, batch, slots

        return _collect


class FrozenRolloutProducer(_RolloutProducer):
    """Env rollout where every actor runs one frozen policy.

    ``producer(params) -> RolloutBatch`` — used by the forward_n and
    threaded regimes, where lag comes from the schedule/timing rather
    than a snapshot mixture.
    """

    def _make_collect(self, env: Env, policy_apply: Callable) -> Callable:
        n_actors, rollout_steps = self.n_actors, self.rollout_steps

        def _collect(params, env_states, key):
            stacked = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (n_actors,) + x.shape
                ),
                params,
            )
            return collect_rollout(
                env, policy_apply, stacked, env_states, key, rollout_steps
            )

        return _collect


# ---------------------------------------------------------------------------
# Regimes
# ---------------------------------------------------------------------------


def _stamp_versions(payload: Any, version: int) -> Any:
    """Overwrite a payload's per-token ``versions`` record with the
    version actually paired with the params the regime handed its
    producer.

    The producer may fill the field itself (it knows the token shape),
    but it reads the store *during* production — under the threaded
    regime a learner publish can land in that window and misattribute
    the whole item.  The regime holds the authoritative
    ``(params, version)`` pair from one ``store.latest()`` call, so it
    gets the last word.  Payloads without the field pass through
    untouched (classic-RL rollouts carry no per-token record).
    """
    versions = getattr(payload, "versions", None)
    if versions is not None and hasattr(payload, "_replace"):
        return payload._replace(
            versions=np.full_like(np.asarray(versions), version))
    return payload


class LagRegime:
    """Driver protocol: start() once, next_item() per consume, stop()."""

    name = "base"
    phase_locked = True   # production driven by the consumer, not a thread

    def __init__(self, store: PolicyStore, queue: TrajectoryQueue) -> None:
        self.store = store
        self.queue = queue

    @property
    def tracer(self):
        """The queue's tracer — one trace covers production, queueing
        and consumption without extra plumbing."""
        return self.queue.tracer

    def start(self) -> None:  # pragma: no cover - trivial
        pass

    def fill(self) -> None:
        pass

    def stop(self) -> None:  # pragma: no cover - trivial
        pass

    def next_item(
        self,
        learner_version: int,
        *,
        timeout: Optional[float] = None,
        max_refills: int = 50,
    ):
        """Next admitted item for the learner.

        Phase-locked regimes produce lazily: when the queue runs dry
        (including after admission drops), ``fill()`` runs again, bounded
        by `max_refills` consecutive all-drop rounds so a pathological
        admission policy terminates the run instead of spinning.
        Threaded regimes just block on the concurrent producer up to
        `timeout`.  Returns None when starved/closed.
        """
        if not self.phase_locked:
            return self.queue.get(
                learner_version=learner_version, timeout=timeout)
        for _ in range(max_refills):
            if self.queue.qsize() == 0:
                self.fill()
            item = self.queue.get(
                learner_version=learner_version, timeout=0.001)
            if item is not None:
                return item
        warnings.warn(
            f"{self.name}: admission policy rejected every item across "
            f"{max_refills} production rounds; the learner is starved and "
            "the run will truncate (check the queue's drops_by_reason "
            "stats, e.g. max_lag tighter than the snapshot mixture).",
            RuntimeWarning,
            stacklevel=2,
        )
        return None


class BackwardMixtureRegime(LagRegime):
    name = "backward_mixture"

    def __init__(
        self,
        store: PolicyStore,
        queue: TrajectoryQueue,
        producer: Callable[[PolicyBuffer], Any],
    ) -> None:
        super().__init__(store, queue)
        self.producer = producer

    def fill(self) -> None:
        buffer, slot_versions, learner_version = self.store.snapshot_state()
        with self.tracer.span("produce", pid="runtime", tid="producer"):
            payload, slots = self.producer(buffer)
        versions = slot_versions[np.asarray(slots)]
        # A mixture item's representative version is its *oldest* policy
        # (conservative for max-lag admission); the full per-actor version
        # vector rides along for lag diagnostics.
        self.queue.put(
            payload,
            behavior_version=int(versions.min()),
            learner_version=learner_version,
            behavior_version_newest=int(versions.max()),
            behavior_versions=versions.tolist(),
        )


class ForwardNRegime(LagRegime):
    name = "forward_n"

    def __init__(
        self,
        store: PolicyStore,
        queue: TrajectoryQueue,
        producer: Callable[[Any], Any],
        *,
        n_items: int,
    ) -> None:
        super().__init__(store, queue)
        self.producer = producer
        self.n_items = n_items

    def fill(self) -> None:
        params, version = self.store.latest()
        for _ in range(self.n_items):
            with self.tracer.span("produce", pid="runtime",
                                  tid="producer", version=version):
                payload = _stamp_versions(self.producer(params), version)
            self.queue.put(
                payload,
                behavior_version=version,
                learner_version=version,
            )


class ThreadedRegime(LagRegime):
    """Real producer thread: generate from the newest snapshot while the
    learner consumes.  ``fill()`` is a no-op — production is continuous.

    With a ``supervisor`` (:class:`~repro.resilience.BackoffPolicy`) the
    loop body runs under watchdog supervision: a crash consumes one
    bounded restart after a seeded backoff delay instead of silently
    starving the queue.  A restarted incarnation re-pins the *current*
    store version and stamps its first item with ``restart=True``
    provenance spanning the outage (``behavior_version`` = the version
    pinned at crash time), so the recovery surfaces at admission as a
    measured ``lag_oldest`` spike rather than bypassing the gate.
    """

    name = "threaded"
    phase_locked = False

    def __init__(
        self,
        store: PolicyStore,
        queue: TrajectoryQueue,
        producer: Callable[[Any], Any],
        *,
        max_items: Optional[int] = None,
        injector: FaultInjector = NULL_INJECTOR,
        supervisor: Optional[BackoffPolicy] = None,
    ) -> None:
        super().__init__(store, queue)
        self.producer = producer
        self.max_items = max_items
        self.injector = injector
        self.supervisor = supervisor
        self._stop_event = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.produced = 0
        self.restarts = 0
        self.error: Optional[BaseException] = None
        self._version_at_crash: Optional[int] = None
        self._restart_floor: Optional[int] = None

    def start(self) -> None:
        self._thread = threading.Thread(
            target=self._loop, name="runtime-producer", daemon=True
        )
        self._thread.start()

    def _restart_meta(self, ctx: RestartContext, version: int) -> tuple:
        """(behavior_version_oldest, meta) for the first item a restarted
        incarnation publishes: conservative provenance spanning the
        outage, so span-aware admission sees the true worst case."""
        oldest = version
        meta = {}
        if ctx.attempt > 0:
            meta = {"restart": True, "restart_attempt": ctx.attempt}
            if self._restart_floor is not None:
                oldest = min(self._restart_floor, version)
                self._restart_floor = None
        return oldest, meta

    def _run(self, ctx: RestartContext) -> None:
        restart_pending = ctx.attempt > 0
        if restart_pending:
            # Freeze the crash-time pin NOW: the loop below re-pins (and
            # overwrites _version_at_crash) every iteration, so the
            # outage floor must be captured before the first one.
            self._restart_floor = self._version_at_crash
        while not self._stop_event.is_set() and (
            self.max_items is None or self.produced < self.max_items
        ):
            # A restarted incarnation re-pins whatever is current *now*.
            params, version = self.store.latest()
            self._version_at_crash = version
            self.injector.crash_if(
                "producer", at_step=self.produced, producer=self.name)
            with self.tracer.span("produce", pid="runtime",
                                  tid="producer", version=version):
                payload = _stamp_versions(
                    self.producer(params), version)
            oldest, meta = (
                self._restart_meta(ctx, version)
                if restart_pending else (version, {}))
            try:
                self.queue.put(
                    payload,
                    behavior_version=oldest,
                    learner_version=self.store.version,
                    behavior_version_newest=(
                        version if oldest != version else None),
                    **meta,
                )
            except QueueClosed:
                break
            restart_pending = False
            self.produced += 1

    def _loop(self) -> None:
        try:
            if self.supervisor is None:
                self._run(RestartContext())
            else:
                self.restarts = supervise(
                    self._run,
                    policy=self.supervisor,
                    name=self.name,
                    should_stop=self._stop_event.is_set,
                    clean_exits=(QueueClosed,),
                    registry=self.queue.registry,
                    tracer=self.tracer,
                )
        except BaseException as e:  # surface producer crashes, don't hang
            self.error = e
        finally:
            # End of production (finite run, stop, or crash): let the
            # learner drain what's left, then see None from get() as the
            # end-of-stream signal instead of blocking on its timeout.
            self.queue.close()

    def next_item(self, learner_version, *, timeout=None, max_refills=50):
        item = super().next_item(
            learner_version, timeout=timeout, max_refills=max_refills)
        if item is None and self.error is not None:
            raise RuntimeError(
                "threaded producer crashed") from self.error
        return item

    def stop(self, join_timeout: float = 30.0) -> None:
        self._stop_event.set()
        self.queue.close()
        if self._thread is not None:
            self._thread.join(timeout=join_timeout)


class EngineThreadedRegime(ThreadedRegime):
    """Producer thread driving a continuous-batching ServeEngine.

    The plain :class:`ThreadedRegime` freezes ``store.latest()`` once
    per produced item, so a trajectory is homogeneous in its behavior
    policy.  Here the *engine* owns the store and may swap weights
    between decode steps (``serve.ServeEngine``), so a single
    trajectory can straddle learner publishes — the intra-trajectory
    policy lag the paper's tokenwise TV gate is built for.  Each queue
    item is one :class:`~repro.serve.engine.ServedTrajectory`; its
    representative ``behavior_version`` is the trajectory's *oldest*
    token version (the mixture regime's conservative convention) and
    the full per-token version vector rides in ``meta``.

    ``request_fn() -> (prompt, max_new_tokens) | None`` feeds the
    engine; None means the request stream is exhausted.  The thread
    keeps ~2 batches of requests in flight so admission always has a
    candidate when a slot frees up.
    """

    name = "threaded_engine"
    phase_locked = False

    def __init__(
        self,
        store: PolicyStore,
        queue: TrajectoryQueue,
        engine: Any,              # serve.ServeEngine bound to `store`
        *,
        request_fn: Callable[[], Optional[tuple]],
        max_items: Optional[int] = None,
        injector: FaultInjector = NULL_INJECTOR,
        supervisor: Optional[BackoffPolicy] = None,
    ) -> None:
        if engine.store is not store:
            raise ValueError(
                "engine must share the regime's PolicyStore (its "
                "in-flight swaps are how learner publishes reach the "
                "actor)")
        super().__init__(store, queue, producer=None, max_items=max_items,
                         injector=injector, supervisor=supervisor)
        self.engine = engine
        self.request_fn = request_fn
        self._source_dry = False

    def _backlog(self) -> int:
        sched = self.engine.scheduler
        return len(sched.waiting) + len(sched.running)

    def _feed(self) -> None:
        target = 2 * self.engine.max_batch
        while not self._source_dry and self._backlog() < target:
            item = self.request_fn()
            if item is None:
                self._source_dry = True
                return
            prompt, max_new_tokens = item
            self.engine.submit(prompt, max_new_tokens)

    def _run(self, ctx: RestartContext) -> None:
        restart_pending = ctx.attempt > 0
        if restart_pending:
            # Re-pin the current store version: the engine's in-flight
            # requests keep their stale per-token provenance (the real
            # recovery lag spike), new tokens come from fresh weights.
            params, version = self.store.latest()
            self.engine.params = params
            self.engine.version = version
        while not self._stop_event.is_set() and (
            self.max_items is None or self.produced < self.max_items
        ):
            self.injector.crash_if(
                "producer", at_step=self.produced, producer=self.name)
            self._feed()
            if not self.engine.has_work:
                break    # stream dry and everything drained
            for traj in self.engine.step():
                self._version_at_crash = int(traj.behavior_version)
                meta = {}
                if restart_pending:
                    meta = {"restart": True,
                            "restart_attempt": ctx.attempt}
                try:
                    self.queue.put(
                        traj,
                        behavior_version=traj.behavior_version,
                        learner_version=self.store.version,
                        behavior_version_newest=int(
                            traj.versions.max()
                        ) if traj.versions.size else None,
                        versions=traj.versions.tolist(),
                        request_id=traj.request_id,
                        finish_reason=traj.finish_reason,
                        **meta,
                    )
                except QueueClosed:
                    return
                restart_pending = False
                self.produced += 1


def make_regime(
    name: str,
    store: PolicyStore,
    queue: TrajectoryQueue,
    producer: Callable,
    *,
    forward_n: int = 4,
    max_items: Optional[int] = None,
    engine: Any = None,
    injector: FaultInjector = NULL_INJECTOR,
    supervisor: Optional[BackoffPolicy] = None,
) -> LagRegime:
    """Factory used by runners and launchers (`--runtime` flag).

    For ``threaded_engine``, `producer` is the request source
    (``request_fn``) and `engine` the ServeEngine bound to `store`.
    ``injector``/``supervisor`` apply to the threaded regimes only
    (phase-locked regimes have no producer thread to crash or watch).
    """
    if name == "backward_mixture":
        return BackwardMixtureRegime(store, queue, producer)
    if name == "forward_n":
        return ForwardNRegime(store, queue, producer, n_items=forward_n)
    if name == "threaded":
        return ThreadedRegime(store, queue, producer, max_items=max_items,
                              injector=injector, supervisor=supervisor)
    if name == "threaded_engine":
        if engine is None:
            raise ValueError("threaded_engine regime requires engine=")
        return EngineThreadedRegime(
            store, queue, engine, request_fn=producer, max_items=max_items,
            injector=injector, supervisor=supervisor)
    raise ValueError(f"unknown lag regime {name!r}")


REGIMES = ("backward_mixture", "forward_n", "threaded", "threaded_engine")
