"""Versioned policy store — the publication side of the async runtime.

Generalizes ``core.policy_lag.PolicyBuffer`` (the paper's Fig. 1 policy
ring) into a store with an explicit, monotonically increasing *version*
counter and per-snapshot metadata.  Both experimental regimes publish and
read through it:

* the classic-RL learner publishes after every train phase and the
  mixture actors sample stale snapshots straight from the underlying
  jit-friendly ring (``store.buffer`` + ``buffer_sample``);
* the RLVR serve loop freezes ``store.latest()`` per generation phase;
* the ``threaded`` regime's producer thread pulls ``latest()``
  concurrently with learner publishes.

The ring keeps the last ``capacity`` snapshots' *parameters*; metadata is
kept for every version ever published (it is tiny).  Snapshot reads hand
back references to immutable jax arrays, so readers never observe a
half-written snapshot: the buffer pytree is swapped atomically under the
lock.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.policy_lag import (
    PolicyBuffer,
    buffer_init,
    buffer_latest,
    buffer_push,
    buffer_sample,
)
from repro.obs.tracer import NULL_TRACER, Tracer
from repro.resilience.faults import FaultInjector, NULL_INJECTOR
from repro.resilience.supervision import tree_all_finite


@dataclass(frozen=True)
class SnapshotMeta:
    """Per-version bookkeeping (kept even after the params are evicted)."""

    version: int
    wall_time: float
    meta: Dict[str, Any] = field(default_factory=dict)


class StaleVersionError(KeyError):
    """Requested a version whose parameters were evicted from the ring."""


class QuarantinedVersionError(KeyError):
    """Requested a version that was quarantined (non-finite publish)."""


class PolicyStore:
    """Bounded ring of policy snapshots with monotonic versioning."""

    def __init__(
        self,
        init_params: Any,
        capacity: int,
        meta: Optional[Dict[str, Any]] = None,
        sharding: Any = None,
        tracer: Tracer = NULL_TRACER,
        injector: FaultInjector = NULL_INJECTOR,
        guard_finite: bool = False,
        registry: Any = None,
    ) -> None:
        """``sharding`` (a ``NamedSharding``, typically
        ``distributed.sharding.replicated(mesh)``) places every
        published snapshot on the mesh at publish time, so sharded
        serve engines read correctly-placed parameters straight from
        ``latest()``/``pin_lagged()`` instead of re-placing them per
        swap.  The ring's stacked pytree inherits the placement
        (eager jnp ops follow their operands' shardings)."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.tracer = tracer
        self._lock = threading.Lock()
        self._sharding = sharding
        if sharding is not None:
            init_params = jax.device_put(init_params, sharding)
        self._buffer: PolicyBuffer = buffer_init(init_params, capacity)
        self._version = 0
        # buffer_init marks the initial policy valid at slot capacity-1
        # (head=0, count=1 => age-order slot (head-count)%cap).
        self._slot_versions = np.zeros(capacity, dtype=np.int64)
        self._history: Dict[int, SnapshotMeta] = {
            0: SnapshotMeta(0, time.time(), dict(meta or {}))
        }
        # version -> [params, refcount]: snapshots kept alive past ring
        # eviction for long-lived readers (speculative-decode drafts).
        self._pinned: Dict[int, List[Any]] = {}
        # Resilience: quarantined versions hold a version number and
        # history entry but never enter the ring, so they can never be
        # resolved, pinned or swapped into a serve engine.
        self.injector = injector
        self.guard_finite = bool(guard_finite)
        self.registry = registry
        self._quarantined: set = set()
        self._publish_calls = 0

    # -- publication ---------------------------------------------------------

    def publish(self, params: Any, **meta: Any) -> int:
        """Insert a new snapshot; returns its (monotonic) version.

        With ``guard_finite`` on, a snapshot with any non-finite array
        leaf is **quarantined** instead of inserted: it consumes a
        version number and a history entry (``quarantined=True``) but
        never enters the ring, so ``latest()``/``resolve_lagged()``
        keep serving the last good snapshot and no actor can swap the
        poison in.  The fault injector's ``nan_publish`` hook runs
        first, so an injected poisoned publish exercises exactly this
        path."""
        with self._lock:
            self._publish_calls += 1
            calls, provisional = self._publish_calls, self._version + 1
        params, poisoned = self.injector.poison(
            "publish", params, at_publish=calls, version=provisional)
        quarantine = self.guard_finite and not tree_all_finite(params)
        if not quarantine and self._sharding is not None:
            # Outside the lock: device placement can be slow and needs
            # no store state.
            params = jax.device_put(params, self._sharding)
        with self._lock:
            self._version += 1
            version = self._version
            if quarantine:
                self._quarantined.add(version)
                meta = dict(meta, quarantined=True, poisoned=poisoned)
            else:
                slot = int(self._buffer.head)
                self._buffer = buffer_push(self._buffer, params)
                self._slot_versions[slot] = self._version
            self._history[version] = SnapshotMeta(
                version, time.time(), dict(meta)
            )
        tr = self.tracer
        if quarantine:
            if self.registry is not None:
                self.registry.counter("publish_quarantined_total").inc()
            if tr.enabled:
                tr.instant("publish_quarantine", pid="runtime", tid="store",
                           version=version, poisoned=poisoned)
            return version
        if tr.enabled:
            tr.instant("publish", pid="runtime", tid="store",
                       version=version)
            tr.counter("policy_version", pid="runtime",
                       version=float(version))
        return version

    # -- quarantine ----------------------------------------------------------

    def quarantine(self, version: int) -> None:
        """Mark ``version`` unserveable: excluded from ``latest()`` and
        lagged resolution, and ``get()`` raises.  (Post-hoc quarantine
        of a version already resident in the ring guards the serve
        read paths; in-graph mixture sampling still sees the slot.)"""
        with self._lock:
            if version not in self._history:
                raise KeyError(f"version {version} was never published")
            self._quarantined.add(version)
        if self.registry is not None:
            self.registry.counter("publish_quarantined_total").inc()
        if self.tracer.enabled:
            self.tracer.instant("publish_quarantine", pid="runtime",
                                tid="store", version=version)

    def is_quarantined(self, version: int) -> bool:
        with self._lock:
            return version in self._quarantined

    def quarantined_versions(self) -> List[int]:
        with self._lock:
            return sorted(self._quarantined)

    # -- reads ---------------------------------------------------------------

    @property
    def version(self) -> int:
        """Latest published version (0 is the init policy)."""
        return self._version

    @property
    def capacity(self) -> int:
        return self._buffer.capacity

    @property
    def buffer(self) -> PolicyBuffer:
        """Jit-friendly ring view for in-graph mixture sampling."""
        return self._buffer

    def snapshot_state(self) -> Tuple[PolicyBuffer, np.ndarray, int]:
        """Consistent (buffer, slot_versions, latest_version) triple."""
        with self._lock:
            return self._buffer, self._slot_versions.copy(), self._version

    def latest(self) -> Tuple[Any, int]:
        """Newest *serveable* snapshot.  When the newest published
        version is quarantined this is the newest good one — the ring
        never holds quarantined params, so ``buffer_latest`` already
        points at it; only the reported version needs adjusting."""
        with self._lock:
            version = self._version
            while version in self._quarantined and version > 0:
                version -= 1
            if version == self._version:
                return buffer_latest(self._buffer), version
            params = self._resident_locked(version)
            if params is None:
                raise QuarantinedVersionError(
                    f"no serveable snapshot: latest good version "
                    f"{version} is no longer resident")
            return params, version

    def retained_versions(self) -> List[int]:
        """Versions whose parameters are still resident, oldest first."""
        with self._lock:
            cap = self._buffer.capacity
            head = int(self._buffer.head)
            count = int(self._buffer.count)
            slots = [(head - count + j) % cap for j in range(count)]
            return [int(self._slot_versions[s]) for s in slots]

    def get(self, version: int) -> Any:
        """Parameters of `version`; StaleVersionError once evicted
        (pinned versions stay readable past eviction)."""
        with self._lock:
            if version in self._quarantined:
                raise QuarantinedVersionError(
                    f"version {version} is quarantined (non-finite "
                    "publish); it cannot be served")
            params = self._resident_locked(version)
            if params is not None:
                return params
        if version in self._history:
            raise StaleVersionError(
                f"version {version} was evicted from the ring "
                f"(capacity {self.capacity}, latest {self._version})"
            )
        raise KeyError(f"version {version} was never published")

    def _resident_locked(self, version: int) -> Optional[Any]:
        """Params of `version` if resident (ring or pin); None otherwise.
        Caller holds the lock."""
        if version in self._pinned:
            return self._pinned[version][0]
        cap = self._buffer.capacity
        head = int(self._buffer.head)
        count = int(self._buffer.count)
        for j in range(count):
            slot = (head - count + j) % cap
            if int(self._slot_versions[slot]) == version:
                return jax.tree.map(
                    lambda s: s[slot], self._buffer.stacked
                )
        return None

    # -- pinning (long-lived readers, e.g. speculative-decode drafts) --------

    def pin(self, version: int) -> Any:
        """Keep `version`'s parameters alive past ring eviction.

        Refcounted: pin the same version twice, release it twice.  The
        version must still be resident (ring or an existing pin) when
        first pinned; afterwards the pin itself keeps it readable by
        :meth:`get` and resolvable by :meth:`resolve_lagged` no matter
        how many publishes evict it from the ring.  Returns the params.
        """
        with self._lock:
            if version in self._pinned:
                self._pinned[version][1] += 1
                self._trace_pin(version)
                return self._pinned[version][0]
            params = self._resident_locked(version)
            if params is not None:
                self._pinned[version] = [params, 1]
                self._trace_pin(version)
                return params
        # Out of the lock: reuse get()'s error taxonomy.
        return self.get(version)

    def _trace_pin(self, version: int) -> None:
        tr = self.tracer
        if tr.enabled:
            tr.instant("pin", pid="runtime", tid="store",
                       version=version, lag=self._version - version)

    def release(self, version: int) -> None:
        """Drop one pin on `version`; params free once refcount hits 0
        (ring residency is unaffected)."""
        with self._lock:
            entry = self._pinned.get(version)
            if entry is None:
                raise KeyError(f"version {version} is not pinned")
            entry[1] -= 1
            if entry[1] <= 0:
                del self._pinned[version]

    def pinned_versions(self) -> List[int]:
        with self._lock:
            return sorted(self._pinned)

    def _resolve_lagged_locked(self, offset: int) -> int:
        target = self._version + offset
        cap = self._buffer.capacity
        head = int(self._buffer.head)
        count = int(self._buffer.count)
        resident = {
            int(self._slot_versions[(head - count + j) % cap])
            for j in range(count)
        }
        resident.update(self._pinned)
        resident -= self._quarantined
        if not resident:
            raise QuarantinedVersionError(
                "no serveable snapshot: every resident version is "
                "quarantined")
        older = [v for v in resident if v <= target]
        return max(older) if older else min(resident)

    def resolve_lagged(self, offset: int) -> int:
        """Resident version closest to ``latest + offset`` (offset <= 0).

        The speculative-decode draft slot asks for "the policy n
        publishes behind the verifier"; when that exact version was
        evicted, the nearest *older* resident one is returned (stalest
        acceptable), falling back to the oldest resident overall.
        Resident = in the ring or pinned.  The result is only
        guaranteed pin-able while no publish intervenes — callers that
        go on to pin should use :meth:`pin_lagged` instead.
        """
        if offset > 0:
            raise ValueError(f"offset must be <= 0, got {offset}")
        with self._lock:
            return self._resolve_lagged_locked(offset)

    def pin_lagged(self, offset: int) -> Tuple[Any, int]:
        """Resolve ``latest + offset`` and pin it in ONE lock hold.

        A separate resolve()-then-pin() pair races concurrent
        publishes: the resolved version (often the oldest resident,
        i.e. the very next eviction victim) can leave the ring between
        the two calls and the pin then raises StaleVersionError —
        exactly the learner-publishes-while-serving interleaving the
        threaded regimes run all day.  Returns ``(params, version)``.
        """
        if offset > 0:
            raise ValueError(f"offset must be <= 0, got {offset}")
        with self._lock:
            version = self._resolve_lagged_locked(offset)
            if version in self._pinned:
                self._pinned[version][1] += 1
                self._trace_pin(version)
                return self._pinned[version][0], version
            params = self._resident_locked(version)
            # Resolution only returns resident versions and the lock is
            # still held, so params cannot be None here.
            self._pinned[version] = [params, 1]
            self._trace_pin(version)
            return params, version

    def meta(self, version: int) -> SnapshotMeta:
        return self._history[version]

    def sample(self, key: jax.Array, n: int) -> Tuple[Any, np.ndarray]:
        """Uniformly sample `n` resident snapshots (host-side convenience).

        Returns (params_batched, versions).  In-graph consumers should
        instead call ``buffer_sample`` on ``store.buffer`` and map the
        returned slots through ``versions_of_slots``.
        """
        buffer, slot_versions, _ = self.snapshot_state()
        params_b, slots = buffer_sample(buffer, key, n)
        return params_b, slot_versions[np.asarray(slots)]

    def versions_of_slots(self, slots: Any) -> np.ndarray:
        """Map ring slots (as sampled in-graph) to policy versions."""
        with self._lock:
            return self._slot_versions[np.asarray(slots)]
