"""Lag controllers at the trajectory-queue boundary (§4.2 and beyond).

The paper applies its TV gate per minibatch inside the loss (Alg. 1 /
``core.tv_filter``).  Here the same estimator guards the *queue boundary*:
whole trajectories whose measured TV against the current policy already
exceeds delta/2 are dropped (or downweighted) before they ever reach the
learner — staleness as a queue/controller property rather than a per-loss
afterthought.

The base class is the :class:`LagController` protocol, which spans the
three decision points the related work needs:

* **consume-time admission** (``admit``): drop / downweight / pass a
  whole item before the learner sees it — the paper's Eq. 8 gate and
  plain max-lag eviction live here;
* **per-token loss weighting** (``loss_weights``): scale the advantage
  of individual tokens, optionally against the learner's *current*
  log-probs (``needs_log_pi``) — variance-controlled truncated
  importance correction (Stable Asynchrony) and behavior-free
  asymmetric scaling (ASymPO) live here;
* **gradient / learner-step feedback** (``transform_gradients`` /
  ``on_learner_step``): act on the minibatch gradient itself
  (``needs_gradients``) — gradient-alignment control (GAC) lives here.

Controllers are evaluated at *consume* time, when the learner's
version — and hence the item's true lag — is known.  Every decision
must carry a non-empty ``reason`` (the queue enforces this); reasons
feed the labelled ``queue_admission_total{controller,outcome,reason}``
counters in the metrics registry.

Construction goes through :mod:`repro.runtime.controllers`
(``--controller "tv_gate:delta=0.2,mode=downweight"`` specs); the
string-keyed :func:`make_admission` factory is kept as a deprecation
shim.
"""
from __future__ import annotations

from typing import (
    Any,
    Callable,
    Dict,
    NamedTuple,
    Optional,
    Tuple,
    TYPE_CHECKING,
)

if TYPE_CHECKING:  # pragma: no cover
    import numpy as np

    from repro.runtime.queue import TrajectoryItem


class AdmissionDecision(NamedTuple):
    admit: bool
    weight: float = 1.0          # importance downweight applied if admitted
    tv: Optional[float] = None   # measured TV when the policy computed one
    reason: str = ""             # mandatory; the queue rejects empty reasons


class LagController:
    """Full lag-mitigation protocol: admission + loss + gradient hooks.

    Subclasses override only the decision points they use; the defaults
    are pass-through at every hook, so an admission-only policy is still
    a complete controller.
    """

    name = "base"
    #: ``loss_weights`` wants the learner's current per-token log-probs.
    needs_log_pi = False
    #: ``transform_gradients`` must see (and may rescale) raw gradients,
    #: forcing the trainer onto the split grad/apply update path.
    needs_gradients = False

    # -- consume-time admission ---------------------------------------------

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        return AdmissionDecision(admit=True, reason="admit")

    # -- per-token loss weighting -------------------------------------------

    def loss_weights(
        self,
        item: "TrajectoryItem",
        *,
        advantages: "np.ndarray",       # [B] sequence-level advantages
        log_beta: "np.ndarray",         # [B, S] behavior log-probs
        mask: "np.ndarray",             # [B, S] valid-token mask
        log_pi: Optional["np.ndarray"] = None,  # [B, S] current log-probs
    ) -> Optional["np.ndarray"]:
        """Per-token multiplier [B, S] on the advantage, or None (no-op).

        ``log_pi`` is only provided when ``needs_log_pi`` is True (it
        costs a scoring forward pass).
        """
        return None

    # -- gradient / learner-step feedback -----------------------------------

    def transform_gradients(
        self, item: "TrajectoryItem", grads: Any
    ) -> Tuple[Any, Dict[str, float]]:
        """Rescale/replace the minibatch gradient pytree; returns
        (grads, info) where ``info`` is merged into the step's aux
        metrics.  Only called when ``needs_gradients`` is True."""
        return grads, {}

    def on_learner_step(
        self, item: "TrajectoryItem", aux: Dict[str, Any]
    ) -> None:
        """Observe the completed learner step (aux metrics included)."""


#: Back-compat alias — admission policies *are* (admission-only)
#: lag controllers.
AdmissionPolicy = LagController


class PassThrough(LagController):
    """Admit everything at full weight (the phase-locked baseline)."""

    name = "pass_through"

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        return AdmissionDecision(admit=True, reason="admit")


class MaxLagEviction(LagController):
    """Drop items older (in learner updates) than ``max_lag`` versions.

    Mixture items (backward_mixture regime, mid-swap served
    trajectories) span a *range* of behavior versions; the item's
    ``lag_oldest``/``lag_newest`` give the explicit span.  The gate is:

    * ``lag_newest > max_lag``: even the freshest token is over-age —
      drop (reason ``max_lag``);
    * ``lag_oldest <= max_lag``: everything is in-age — admit at full
      weight;
    * span straddles the cutoff: admit downweighted by the in-age
      fraction (per-snapshot when ``meta["behavior_versions"]`` is
      present, linear in the lag span otherwise; reason
      ``max_lag_span``), so a mostly-fresh mixture is no longer
      dropped for its single oldest snapshot.
    """

    name = "max_lag"
    min_weight = 1e-3

    def __init__(self, max_lag: int) -> None:
        if max_lag < 0:
            raise ValueError(f"max_lag must be >= 0, got {max_lag}")
        self.max_lag = max_lag

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        if item.lag_newest > self.max_lag:
            return AdmissionDecision(admit=False, reason="max_lag")
        if item.lag_oldest <= self.max_lag:
            return AdmissionDecision(admit=True, reason="admit")
        versions = item.meta.get("behavior_versions")
        ref = item.behavior_version + item.lag_oldest
        if versions:
            lags = [ref - int(v) for v in versions]
            frac = sum(1 for l in lags if l <= self.max_lag) / len(lags)
        else:
            span = item.lag_oldest - item.lag_newest + 1
            frac = (self.max_lag - item.lag_newest + 1) / span
        if not frac >= self.min_weight:
            return AdmissionDecision(admit=False, reason="max_lag")
        return AdmissionDecision(
            admit=True, weight=float(frac), reason="max_lag_span")


class TVGatedAdmission(LagController):
    """Gate on the sampled TV estimate (Eq. 8) against the current policy.

    ``tv_fn(payload) -> float`` measures the expected total variation
    between the *current* learner policy and the trajectory's behavior
    policy on the trajectory's own states/actions (the caller closes over
    the policy store and the model apply).  Items with tv <= delta/2 pass
    untouched; over-threshold items are dropped (``mode='drop'``) or
    admitted at weight (delta/2)/tv (``mode='downweight'``).
    """

    name = "tv_gate"

    # Downweighted items below this weight are dropped instead: a
    # near-zero-weight trajectory still costs a full learner step but
    # contributes nothing (tv -> inf gives weight 0 exactly, which
    # would silently train on dead data).
    min_weight = 1e-3

    def __init__(
        self,
        delta: float,
        tv_fn: Callable[[Any], float],
        mode: str = "drop",
    ) -> None:
        if mode not in ("drop", "downweight"):
            raise ValueError(f"mode must be drop|downweight, got {mode!r}")
        self.delta = float(delta)
        self.tv_fn = tv_fn
        self.mode = mode

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        tv = float(self.tv_fn(item.payload))
        threshold = self.delta / 2.0
        if tv <= threshold:
            return AdmissionDecision(admit=True, tv=tv, reason="admit")
        if self.mode == "downweight":
            weight = threshold / tv if tv > 0 else 0.0
            if not weight >= self.min_weight:   # catches 0.0 and nan
                return AdmissionDecision(
                    admit=False, tv=tv, reason="tv_zero_weight")
            return AdmissionDecision(
                admit=True, weight=weight, tv=tv,
                reason="tv_downweight",
            )
        return AdmissionDecision(admit=False, tv=tv, reason="tv_gate")


class TokenwiseTVGate(LagController):
    """Eq. 8 applied per *version segment* of a served trajectory.

    The continuous-batching serve engine swaps weights in-flight, so a
    single trajectory can straddle several behavior policies; its
    payload carries per-token policy versions.  Whole-trajectory gating
    averages the TV estimate over all tokens — a long fresh prefix can
    mask a badly stale tail (and vice versa).  This policy segments the
    trajectory at version boundaries, applies the paper's gate to each
    segment's own TV estimate, and admits at the token-weighted mean of
    the per-segment weights (1 for in-trust segments, (delta/2)/tv_s
    downweighted or 0 dropped for the rest).

    ``token_tv_fn(payload) -> (tv_tokens, versions)``: per-token sampled
    TV terms 0.5*|ratio_t - 1| against the current policy, and the
    per-token behavior versions (both [N]); the caller closes over the
    policy store and model apply exactly as for ``tv_gate``.  Per-segment
    decisions land in ``item.meta["tv_segments"]`` for metrics.
    """

    name = "tv_gate_tokenwise"
    min_weight = TVGatedAdmission.min_weight

    def __init__(
        self,
        delta: float,
        token_tv_fn: Callable[[Any], Any],
        mode: str = "downweight",
    ) -> None:
        if mode not in ("drop", "downweight"):
            raise ValueError(f"mode must be drop|downweight, got {mode!r}")
        self.delta = float(delta)
        self.token_tv_fn = token_tv_fn
        self.mode = mode

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        import numpy as np

        tv_tokens, versions = self.token_tv_fn(item.payload)
        tv_tokens = np.asarray(tv_tokens, np.float64).reshape(-1)
        versions = np.asarray(versions).reshape(-1)
        n = tv_tokens.shape[0]
        if versions.shape[0] != n:
            raise ValueError(
                f"tv/versions length mismatch: {n} vs {versions.shape[0]}")
        if n == 0:
            return AdmissionDecision(admit=True, tv=0.0, reason="admit")
        threshold = self.delta / 2.0
        # Segment boundaries where the producing policy version changes.
        cuts = [0] + (
            1 + np.flatnonzero(versions[1:] != versions[:-1])
        ).tolist() + [n]
        segments = []
        weighted_tv = 0.0
        weighted_w = 0.0
        for lo, hi in zip(cuts[:-1], cuts[1:]):
            tv_s = float(tv_tokens[lo:hi].mean())     # Eq. 8 per segment
            if tv_s <= threshold:
                w_s = 1.0
            elif self.mode == "downweight" and tv_s > 0:
                w_s = threshold / tv_s
            else:
                w_s = 0.0
            segments.append(
                {"version": int(versions[lo]), "tokens": hi - lo,
                 "tv": tv_s, "weight": w_s})
            weighted_tv += (hi - lo) * tv_s
            weighted_w += (hi - lo) * w_s
        tv = weighted_tv / n
        weight = weighted_w / n
        item.meta["tv_segments"] = segments
        if not weight >= self.min_weight:
            return AdmissionDecision(
                admit=False, tv=tv, reason="tv_gate_tokenwise")
        reason = "tv_tokenwise_downweight" if weight < 1.0 else "admit"
        return AdmissionDecision(
            admit=True, weight=weight, tv=tv, reason=reason)


def make_admission(
    name: str,
    *,
    max_lag: int = 4,
    delta: float = 0.2,
    tv_fn: Optional[Callable[[Any], float]] = None,
    mode: str = "drop",
) -> LagController:
    """Deprecated string-keyed factory (the legacy ``--admission`` path).

    Thin shim over :func:`repro.runtime.controllers.make_controller`;
    new call sites should build a :class:`ControllerSpec` instead.
    """
    import warnings

    from repro.runtime.controllers import make_controller, spec_from_legacy

    warnings.warn(
        "make_admission() is deprecated; use "
        "repro.runtime.controllers.make_controller(parse_controller_spec"
        "(...)) — e.g. --controller 'tv_gate:delta=0.2,mode=downweight'",
        DeprecationWarning, stacklevel=2)
    spec = spec_from_legacy(name, max_lag=max_lag, delta=delta, mode=mode)
    return make_controller(spec, tv_fn=tv_fn, token_tv_fn=tv_fn)
