"""Admission policies for the trajectory queue (§4.2 at the queue boundary).

The paper applies its TV gate per minibatch inside the loss (Alg. 1 /
``core.tv_filter``).  Here the same estimator guards the *queue boundary*:
whole trajectories whose measured TV against the current policy already
exceeds delta/2 are dropped (or downweighted) before they ever reach the
learner — staleness as a queue/controller property rather than a per-loss
afterthought (GAC; Stable Asynchrony).

Policies are evaluated at *consume* time, when the learner's version — and
hence the item's true lag — is known.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from repro.runtime.queue import TrajectoryItem


class AdmissionDecision(NamedTuple):
    admit: bool
    weight: float = 1.0          # importance downweight applied if admitted
    tv: Optional[float] = None   # measured TV when the policy computed one
    reason: str = ""             # drop/downweight reason for metrics


class AdmissionPolicy:
    """Decide whether a consumed trajectory reaches the learner."""

    name = "base"

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        raise NotImplementedError


class PassThrough(AdmissionPolicy):
    """Admit everything at full weight (the phase-locked baseline)."""

    name = "pass_through"

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        return AdmissionDecision(admit=True)


class MaxLagEviction(AdmissionPolicy):
    """Drop items older (in learner updates) than `max_lag` versions.

    Note on mixture items (backward_mixture regime): the item's
    representative ``behavior_version`` is the *oldest* snapshot any
    actor sampled, so with a snapshot ring deeper than `max_lag` most
    mixtures contain at least one over-age policy and get dropped —
    choose max_lag >= buffer capacity (or use tv_gate) for that regime,
    or expect heavy drop rates in ``drops_by_reason``.
    """

    name = "max_lag"

    def __init__(self, max_lag: int) -> None:
        if max_lag < 0:
            raise ValueError(f"max_lag must be >= 0, got {max_lag}")
        self.max_lag = max_lag

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        if item.lag > self.max_lag:
            return AdmissionDecision(admit=False, reason="max_lag")
        return AdmissionDecision(admit=True)


class TVGatedAdmission(AdmissionPolicy):
    """Gate on the sampled TV estimate (Eq. 8) against the current policy.

    ``tv_fn(payload) -> float`` measures the expected total variation
    between the *current* learner policy and the trajectory's behavior
    policy on the trajectory's own states/actions (the caller closes over
    the policy store and the model apply).  Items with tv <= delta/2 pass
    untouched; over-threshold items are dropped (``mode='drop'``) or
    admitted at weight (delta/2)/tv (``mode='downweight'``).
    """

    name = "tv_gate"

    def __init__(
        self,
        delta: float,
        tv_fn: Callable[[Any], float],
        mode: str = "drop",
    ) -> None:
        if mode not in ("drop", "downweight"):
            raise ValueError(f"mode must be drop|downweight, got {mode!r}")
        self.delta = float(delta)
        self.tv_fn = tv_fn
        self.mode = mode

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        tv = float(self.tv_fn(item.payload))
        threshold = self.delta / 2.0
        if tv <= threshold:
            return AdmissionDecision(admit=True, tv=tv)
        if self.mode == "downweight":
            return AdmissionDecision(
                admit=True, weight=threshold / tv, tv=tv,
                reason="tv_downweight",
            )
        return AdmissionDecision(admit=False, tv=tv, reason="tv_gate")


def make_admission(
    name: str,
    *,
    max_lag: int = 4,
    delta: float = 0.2,
    tv_fn: Optional[Callable[[Any], float]] = None,
    mode: str = "drop",
) -> AdmissionPolicy:
    """Factory used by launchers/runners (`--admission` flag)."""
    if name == "pass_through":
        return PassThrough()
    if name == "max_lag":
        return MaxLagEviction(max_lag)
    if name == "tv_gate":
        if tv_fn is None:
            raise ValueError("tv_gate admission requires a tv_fn")
        return TVGatedAdmission(delta, tv_fn, mode=mode)
    raise ValueError(f"unknown admission policy {name!r}")
