"""Admission policies for the trajectory queue (§4.2 at the queue boundary).

The paper applies its TV gate per minibatch inside the loss (Alg. 1 /
``core.tv_filter``).  Here the same estimator guards the *queue boundary*:
whole trajectories whose measured TV against the current policy already
exceeds delta/2 are dropped (or downweighted) before they ever reach the
learner — staleness as a queue/controller property rather than a per-loss
afterthought (GAC; Stable Asynchrony).

Policies are evaluated at *consume* time, when the learner's version — and
hence the item's true lag — is known.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from repro.runtime.queue import TrajectoryItem


class AdmissionDecision(NamedTuple):
    admit: bool
    weight: float = 1.0          # importance downweight applied if admitted
    tv: Optional[float] = None   # measured TV when the policy computed one
    reason: str = ""             # drop/downweight reason for metrics


class AdmissionPolicy:
    """Decide whether a consumed trajectory reaches the learner."""

    name = "base"

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        raise NotImplementedError


class PassThrough(AdmissionPolicy):
    """Admit everything at full weight (the phase-locked baseline)."""

    name = "pass_through"

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        return AdmissionDecision(admit=True)


class MaxLagEviction(AdmissionPolicy):
    """Drop items older (in learner updates) than `max_lag` versions.

    Note on mixture items (backward_mixture regime): the item's
    representative ``behavior_version`` is the *oldest* snapshot any
    actor sampled, so with a snapshot ring deeper than `max_lag` most
    mixtures contain at least one over-age policy and get dropped —
    choose max_lag >= buffer capacity (or use tv_gate) for that regime,
    or expect heavy drop rates in ``drops_by_reason``.
    """

    name = "max_lag"

    def __init__(self, max_lag: int) -> None:
        if max_lag < 0:
            raise ValueError(f"max_lag must be >= 0, got {max_lag}")
        self.max_lag = max_lag

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        if item.lag > self.max_lag:
            return AdmissionDecision(admit=False, reason="max_lag")
        return AdmissionDecision(admit=True)


class TVGatedAdmission(AdmissionPolicy):
    """Gate on the sampled TV estimate (Eq. 8) against the current policy.

    ``tv_fn(payload) -> float`` measures the expected total variation
    between the *current* learner policy and the trajectory's behavior
    policy on the trajectory's own states/actions (the caller closes over
    the policy store and the model apply).  Items with tv <= delta/2 pass
    untouched; over-threshold items are dropped (``mode='drop'``) or
    admitted at weight (delta/2)/tv (``mode='downweight'``).
    """

    name = "tv_gate"

    # Downweighted items below this weight are dropped instead: a
    # near-zero-weight trajectory still costs a full learner step but
    # contributes nothing (tv -> inf gives weight 0 exactly, which
    # would silently train on dead data).
    min_weight = 1e-3

    def __init__(
        self,
        delta: float,
        tv_fn: Callable[[Any], float],
        mode: str = "drop",
    ) -> None:
        if mode not in ("drop", "downweight"):
            raise ValueError(f"mode must be drop|downweight, got {mode!r}")
        self.delta = float(delta)
        self.tv_fn = tv_fn
        self.mode = mode

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        tv = float(self.tv_fn(item.payload))
        threshold = self.delta / 2.0
        if tv <= threshold:
            return AdmissionDecision(admit=True, tv=tv)
        if self.mode == "downweight":
            weight = threshold / tv if tv > 0 else 0.0
            if not weight >= self.min_weight:   # catches 0.0 and nan
                return AdmissionDecision(
                    admit=False, tv=tv, reason="tv_zero_weight")
            return AdmissionDecision(
                admit=True, weight=weight, tv=tv,
                reason="tv_downweight",
            )
        return AdmissionDecision(admit=False, tv=tv, reason="tv_gate")


class TokenwiseTVGate(AdmissionPolicy):
    """Eq. 8 applied per *version segment* of a served trajectory.

    The continuous-batching serve engine swaps weights in-flight, so a
    single trajectory can straddle several behavior policies; its
    payload carries per-token policy versions.  Whole-trajectory gating
    averages the TV estimate over all tokens — a long fresh prefix can
    mask a badly stale tail (and vice versa).  This policy segments the
    trajectory at version boundaries, applies the paper's gate to each
    segment's own TV estimate, and admits at the token-weighted mean of
    the per-segment weights (1 for in-trust segments, (delta/2)/tv_s
    downweighted or 0 dropped for the rest).

    ``token_tv_fn(payload) -> (tv_tokens, versions)``: per-token sampled
    TV terms 0.5*|ratio_t - 1| against the current policy, and the
    per-token behavior versions (both [N]); the caller closes over the
    policy store and model apply exactly as for ``tv_gate``.  Per-segment
    decisions land in ``item.meta["tv_segments"]`` for metrics.
    """

    name = "tv_gate_tokenwise"
    min_weight = TVGatedAdmission.min_weight

    def __init__(
        self,
        delta: float,
        token_tv_fn: Callable[[Any], Any],
        mode: str = "downweight",
    ) -> None:
        if mode not in ("drop", "downweight"):
            raise ValueError(f"mode must be drop|downweight, got {mode!r}")
        self.delta = float(delta)
        self.token_tv_fn = token_tv_fn
        self.mode = mode

    def admit(self, item: "TrajectoryItem") -> AdmissionDecision:
        import numpy as np

        tv_tokens, versions = self.token_tv_fn(item.payload)
        tv_tokens = np.asarray(tv_tokens, np.float64).reshape(-1)
        versions = np.asarray(versions).reshape(-1)
        n = tv_tokens.shape[0]
        if versions.shape[0] != n:
            raise ValueError(
                f"tv/versions length mismatch: {n} vs {versions.shape[0]}")
        if n == 0:
            return AdmissionDecision(admit=True, tv=0.0)
        threshold = self.delta / 2.0
        # Segment boundaries where the producing policy version changes.
        cuts = [0] + (
            1 + np.flatnonzero(versions[1:] != versions[:-1])
        ).tolist() + [n]
        segments = []
        weighted_tv = 0.0
        weighted_w = 0.0
        for lo, hi in zip(cuts[:-1], cuts[1:]):
            tv_s = float(tv_tokens[lo:hi].mean())     # Eq. 8 per segment
            if tv_s <= threshold:
                w_s = 1.0
            elif self.mode == "downweight" and tv_s > 0:
                w_s = threshold / tv_s
            else:
                w_s = 0.0
            segments.append(
                {"version": int(versions[lo]), "tokens": hi - lo,
                 "tv": tv_s, "weight": w_s})
            weighted_tv += (hi - lo) * tv_s
            weighted_w += (hi - lo) * w_s
        tv = weighted_tv / n
        weight = weighted_w / n
        item.meta["tv_segments"] = segments
        if not weight >= self.min_weight:
            return AdmissionDecision(
                admit=False, tv=tv, reason="tv_gate_tokenwise")
        reason = "tv_tokenwise_downweight" if weight < 1.0 else ""
        return AdmissionDecision(
            admit=True, weight=weight, tv=tv, reason=reason)


def make_admission(
    name: str,
    *,
    max_lag: int = 4,
    delta: float = 0.2,
    tv_fn: Optional[Callable[[Any], float]] = None,
    mode: str = "drop",
) -> AdmissionPolicy:
    """Factory used by launchers/runners (`--admission` flag)."""
    if name == "pass_through":
        return PassThrough()
    if name == "max_lag":
        return MaxLagEviction(max_lag)
    if name == "tv_gate":
        if tv_fn is None:
            raise ValueError("tv_gate admission requires a tv_fn")
        return TVGatedAdmission(delta, tv_fn, mode=mode)
    if name == "tv_gate_tokenwise":
        if tv_fn is None:
            raise ValueError(
                "tv_gate_tokenwise admission requires a tv_fn returning "
                "(tv_tokens, versions)")
        return TokenwiseTVGate(delta, tv_fn, mode=mode)
    raise ValueError(f"unknown admission policy {name!r}")
