"""Asynchronous actor-learner runtime.

One subsystem behind both of the paper's experimental regimes (and a
genuinely concurrent third): a versioned :class:`PolicyStore` that
learners publish to and actors sample from, a staleness-tagged
:class:`TrajectoryQueue` with pluggable lag control at the queue
boundary (and beyond: per-token loss weighting and gradient feedback
via the :class:`LagController` protocol), and interchangeable lag
regimes — including the serve-backed :class:`ServeRolloutProducer` —
driving the same API.

Controllers are built from ``"name:key=val,..."`` specs
(:func:`parse_controller_spec` / :func:`make_controller`); the
string-keyed :func:`make_admission` factory survives as a deprecation
shim.
"""
from repro.runtime.admission import (
    AdmissionDecision,
    AdmissionPolicy,
    LagController,
    MaxLagEviction,
    PassThrough,
    TokenwiseTVGate,
    TVGatedAdmission,
    make_admission,
)
from repro.runtime.controllers import (
    AsymPOController,
    ControllerContext,
    ControllerSpec,
    GradientAlignmentController,
    StableAsyncController,
    available_controllers,
    make_controller,
    parse_controller_spec,
    register_controller,
    spec_from_legacy,
)
from repro.runtime.policy_store import (
    PolicyStore,
    QuarantinedVersionError,
    SnapshotMeta,
    StaleVersionError,
)
from repro.runtime.queue import QueueClosed, TrajectoryItem, TrajectoryQueue
from repro.runtime.regimes import (
    REGIMES,
    BackwardMixtureRegime,
    EngineThreadedRegime,
    ForwardNRegime,
    FrozenRolloutProducer,
    LagRegime,
    MixtureRolloutProducer,
    ThreadedRegime,
    make_regime,
)
from repro.runtime.serve_producer import ServeRolloutProducer

__all__ = [
    "AdmissionDecision",
    "AdmissionPolicy",
    "LagController",
    "MaxLagEviction",
    "PassThrough",
    "TokenwiseTVGate",
    "TVGatedAdmission",
    "make_admission",
    "AsymPOController",
    "ControllerContext",
    "ControllerSpec",
    "GradientAlignmentController",
    "StableAsyncController",
    "available_controllers",
    "make_controller",
    "parse_controller_spec",
    "register_controller",
    "spec_from_legacy",
    "PolicyStore",
    "QuarantinedVersionError",
    "SnapshotMeta",
    "StaleVersionError",
    "QueueClosed",
    "TrajectoryItem",
    "TrajectoryQueue",
    "REGIMES",
    "BackwardMixtureRegime",
    "EngineThreadedRegime",
    "ForwardNRegime",
    "FrozenRolloutProducer",
    "LagRegime",
    "MixtureRolloutProducer",
    "ThreadedRegime",
    "make_regime",
    "ServeRolloutProducer",
]
