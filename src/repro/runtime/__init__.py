"""Asynchronous actor-learner runtime.

One subsystem behind both of the paper's experimental regimes (and a
genuinely concurrent third): a versioned :class:`PolicyStore` that
learners publish to and actors sample from, a staleness-tagged
:class:`TrajectoryQueue` with pluggable admission control at the queue
boundary, and three interchangeable lag regimes driving the same API.
"""
from repro.runtime.admission import (
    AdmissionDecision,
    AdmissionPolicy,
    MaxLagEviction,
    PassThrough,
    TokenwiseTVGate,
    TVGatedAdmission,
    make_admission,
)
from repro.runtime.policy_store import (
    PolicyStore,
    SnapshotMeta,
    StaleVersionError,
)
from repro.runtime.queue import QueueClosed, TrajectoryItem, TrajectoryQueue
from repro.runtime.regimes import (
    REGIMES,
    BackwardMixtureRegime,
    EngineThreadedRegime,
    ForwardNRegime,
    FrozenRolloutProducer,
    LagRegime,
    MixtureRolloutProducer,
    ThreadedRegime,
    make_regime,
)

__all__ = [
    "AdmissionDecision",
    "AdmissionPolicy",
    "MaxLagEviction",
    "PassThrough",
    "TokenwiseTVGate",
    "TVGatedAdmission",
    "make_admission",
    "PolicyStore",
    "SnapshotMeta",
    "StaleVersionError",
    "QueueClosed",
    "TrajectoryItem",
    "TrajectoryQueue",
    "REGIMES",
    "BackwardMixtureRegime",
    "EngineThreadedRegime",
    "ForwardNRegime",
    "FrozenRolloutProducer",
    "LagRegime",
    "MixtureRolloutProducer",
    "ThreadedRegime",
    "make_regime",
]
