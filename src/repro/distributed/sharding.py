"""Sharding rules for the production meshes.

Default layout (the paper-faithful baseline recorded in EXPERIMENTS.md):

* **weights** — FSDP-style: every parameter is sharded over the ``model``
  axis on its largest mesh-divisible dimension.  This is divisibility-
  robust across the assigned archs (hymba's 25 heads, whisper's 20 make
  head-count tensor-parallel non-portable) — GSPMD then all-gathers
  weights per layer.
* **activations/batch** — batch dim over ``('pod', 'data')`` when
  divisible (train_4k: 256 -> 8/chip on 2x16x16).
* **long_500k decode** — batch=1, so the KV cache shards its *sequence*
  axis over ``data``; softmax over the sharded axis lowers to the
  max/sum all-reduce pair (distributed flash-decode without shard_map).
* **MoE** — expert-bank weights shard the expert axis over ``model``
  (expert parallelism -> all-to-all at the dispatch/combine boundary);
  group axis of the dispatch follows the batch sharding.

The §Perf hillclimbs override these per-arch via ``Overrides`` (e.g. true
tensor-parallel for divisible archs, 2D (data, model) weight sharding) —
see EXPERIMENTS.md for measured deltas.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: Mesh) -> str:
    return "model"


def _axis_size(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    return int(np.prod([mesh.shape[n] for n in names]))


@dataclass(frozen=True)
class ShardingPolicy:
    """Knobs the perf loop hillclimbs."""

    weight_mode: str = "fsdp"      # fsdp | tensor | replicated | fsdp2d
    shard_moe_experts: bool = True
    batch_over_pod: bool = True
    # batch_mode "data": batch over ('pod','data') only (baseline).
    # batch_mode "dp_all": batch over EVERY mesh axis (256/512-way pure
    # data parallelism) with weights still ZeRO-sharded over 'model' —
    # turns the per-layer activation all-reduces into cheap per-layer
    # weight all-gathers (EXPERIMENTS.md §Perf hillclimb #1).
    batch_mode: str = "data"


def param_spec(
    path: str,
    shape: Tuple[int, ...],
    mesh: Mesh,
    policy: ShardingPolicy,
) -> P:
    """PartitionSpec for one parameter leaf."""
    m = model_axis(mesh)
    msize = mesh.shape[m]
    if policy.weight_mode == "replicated" or not shape:
        return P()

    dims: list = [None] * len(shape)

    is_expert_bank = any(
        k in path for k in ("gate_w", "up_w", "down_w")
    ) and len(shape) >= 3
    # Stacked layer params carry a leading [L] axis (scan over layers):
    # never shard it (it is the scan axis).
    start = 1 if "layers" in path and len(shape) > 1 else 0

    if is_expert_bank and policy.shard_moe_experts:
        # [(L,) E, d_in, d_out] — expert parallelism over 'model'.
        e_dim = start
        if shape[e_dim] % msize == 0:
            dims[e_dim] = m
            return P(*dims)

    if policy.weight_mode == "tensor":
        # Megatron-ish: shard the OUTPUT dim of up/gate/qkv, the INPUT dim
        # of down/wo (falls back to fsdp choice when not divisible).
        prefer = len(shape) - 1
        if any(k in path for k in ("down", "wo", "w_cv")):
            prefer = max(start, len(shape) - 2)
        if shape[prefer] % msize == 0:
            dims[prefer] = m
            return P(*dims)

    # FSDP: largest divisible trailing dim.
    order = sorted(
        range(start, len(shape)), key=lambda i: shape[i], reverse=True
    )
    for i in order:
        if shape[i] % msize == 0 and shape[i] >= msize:
            dims[i] = m
            if policy.weight_mode == "fsdp2d":
                # also shard a second dim over the data axes if divisible.
                d_axes = data_axes(mesh)
                dsize = _axis_size(mesh, d_axes)
                for j in order:
                    if j != i and shape[j] % dsize == 0 and shape[j] >= dsize:
                        dims[j] = d_axes if len(d_axes) > 1 else d_axes[0]
                        break
            return P(*dims)
    return P()


def params_shardings(params: Any, mesh: Mesh,
                     policy: Optional[ShardingPolicy] = None) -> Any:
    """NamedSharding pytree matching `params` (works on ShapeDtypeStructs)."""
    policy = policy or ShardingPolicy()
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        specs.append(
            NamedSharding(mesh, param_spec(pstr, leaf.shape, mesh, policy))
        )
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_dim_axes(mesh: Mesh, batch: int,
                   policy: Optional[ShardingPolicy] = None):
    """Mesh axes to shard a batch dim of the given size, or None."""
    policy = policy or ShardingPolicy()
    if policy.batch_mode == "dp_all":
        axes = tuple(mesh.axis_names)
        if batch % _axis_size(mesh, axes) == 0:
            return axes
    axes = data_axes(mesh) if policy.batch_over_pod else ("data",)
    if batch % _axis_size(mesh, axes) == 0:
        return axes if len(axes) > 1 else axes[0]
    if batch % mesh.shape["data"] == 0:
        return "data"
    return None


def batch_sharding(mesh: Mesh, batch: int, ndim: int,
                   policy: Optional[ShardingPolicy] = None) -> NamedSharding:
    ax = batch_dim_axes(mesh, batch, policy)
    dims = [ax] + [None] * (ndim - 1)
    return NamedSharding(mesh, P(*dims))


def cache_shardings(
    cache: Any,
    mesh: Mesh,
    *,
    shard_seq: bool,
    policy: Optional[ShardingPolicy] = None,
) -> Any:
    """Shardings for the decode cache.

    Layout per leaf: k/v are [L, B, S, KV, Dh]; recurrent states are
    [L, B, ...]; pos is [B].  ``shard_seq=True`` (long_500k) places the
    cache sequence axis on ``data``; otherwise the batch axis carries the
    data axes.
    """
    policy = policy or ShardingPolicy()
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        shape = leaf.shape
        if pstr.endswith("['pos']"):
            out.append(NamedSharding(mesh, P()))
            continue
        dims: list = [None] * len(shape)
        is_kv = ("['k']" in pstr or "['v']" in pstr) and len(shape) == 5
        if is_kv:
            if shard_seq and shape[2] % mesh.shape["data"] == 0:
                dims[2] = "data"
            else:
                ax = batch_dim_axes(mesh, shape[1], policy)
                dims[1] = ax
        elif pstr.endswith("['enc']") and len(shape) == 3:
            ax = batch_dim_axes(mesh, shape[0], policy)
            dims[0] = ax
        elif len(shape) >= 2:
            # recurrent states [L, B, ...]: shard batch when possible.
            ax = batch_dim_axes(mesh, shape[1], policy)
            dims[1] = ax
        out.append(NamedSharding(mesh, P(*dims)))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# -- serve-path (paged pool) shardings ---------------------------------------


def paged_pool_sharding(mesh: Mesh, axis_name: str = "data"
                        ) -> NamedSharding:
    """Placement of the ``[L, KV, NB, BS, Dh]`` paged KV pool: the NB
    (page) axis shards over the mesh's data axis — the layout PR 2
    chose precisely so this split is clean.  Each device holds
    ``NB / data`` whole pages; block tables stay host-side and carry
    shard-local ids (see ``repro.serve.paged_cache``)."""
    return NamedSharding(mesh, P(None, None, axis_name, None, None))


def shard_paged_pool(pages: Any, mesh: Optional[Mesh],
                     axis_name: str = "data") -> Any:
    """Place a paged pool pytree onto the mesh (identity when mesh is
    None — the single-device path is the unsharded special case)."""
    if mesh is None:
        return pages
    return jax.device_put(pages, paged_pool_sharding(mesh, axis_name))
