from repro.distributed.sharding import (
    ShardingPolicy,
    params_shardings,
    batch_sharding,
    cache_shardings,
    replicated,
    param_spec,
    data_axes,
)

__all__ = [
    "ShardingPolicy",
    "params_shardings",
    "batch_sharding",
    "cache_shardings",
    "replicated",
    "param_spec",
    "data_axes",
]
