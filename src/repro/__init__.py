"""repro — a JAX asynchronous on-policy RL framework implementing VACO.

Reproduction of "Align and Filter: Improving Performance in Asynchronous
On-Policy RL" (Honari et al., 2026): total-Variation-based Advantage-aligned
Constrained policy Optimization (VACO), built as a production-grade
multi-pod JAX training/serving framework.

Public surface:
    repro.core         -- VACO, V-trace realignment, TV filtering, baselines
    repro.models       -- policy backbones (dense/MoE/SSM/hybrid/enc-dec/VLM)
    repro.configs      -- assigned architecture configs + input-shape suite
    repro.kernels      -- Pallas TPU kernels (+ jnp oracles)
    repro.envs         -- pure-JAX control environments
    repro.rollout      -- serve engine + async actor-learner simulator
    repro.train        -- classic-RL and RLVR trainers
    repro.launch       -- production meshes, dry-run, launchers
"""

__version__ = "1.0.0"
